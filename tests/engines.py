"""Reusable engine-parity harness (the PR-4 pinning fixture).

Builds the same FMBI / grafted-AMBI tables and runs every query engine the
repo has over them:

  * the NumPy ``NodeTable`` engine (``core/queries.py``) — the
    paper-faithful authority,
  * the single compiled ``DeviceTable`` engine (``core/queries_jax.py``),
  * the m-shard distributed engine (``core/distributed_jax.py``) for each
    requested shard count,

and asserts id-identical results, the same way ``test_flat_queries.py``
pinned the PR-2 flat engine and ``test_queries_jax.py`` pinned the PR-3
device engine.  Windows compare as id sets (result order is unspecified
across engines); k-NN compares ascending id sequences on continuous data
and falls back to distance-sequence equality when the workload carries
exact ties (grid data), mirroring the queries_jax parity contract.

All generated coordinates are float32-representable so the f32 device
engines agree bit-for-bit with the f64 host engine.
"""
import numpy as np

from repro.core import (
    AMBI,
    PageStore,
    bulk_load,
    knn_query_batch,
    window_query_batch,
)
from repro.core.distributed_jax import (
    ShardedDeviceTable,
    knn_query_batch_sharded,
    window_query_batch_sharded,
)
from repro.core.queries_jax import (
    DeviceTable,
    knn_query_batch_jax,
    window_query_batch_jax,
)


# --------------------------------------------------------------------------
# workloads: float32-representable point sets + index builders
# --------------------------------------------------------------------------
def f32_points(n, d, seed, kind="uniform"):
    """Float32-representable coordinates (stored as float64)."""
    rng = np.random.default_rng(seed)
    if kind == "skew":
        pts = rng.random((n, d)) ** 3
    elif kind == "grid":  # heavy duplication, exact f32 arithmetic
        pts = rng.integers(0, 48, (n, d)) / np.float64(64.0)
    else:
        pts = rng.random((n, d))
    return pts.astype(np.float32).astype(np.float64)


def build_fmbi(pts, M=250):
    return bulk_load(pts, M, PageStore(M))


def build_grafted_ambi(pts, M=250):
    """A fully refined AMBI index whose table rows were grafted on demand
    (not level-contiguous — the layout case the device engines must
    normalize)."""
    ambi = AMBI(pts, M)
    d = pts.shape[1]
    rng = np.random.default_rng(0)
    for _ in range(4):  # partial refinement first: interleaved grafts
        c = rng.random(d)
        ambi.window(c - 0.05, c + 0.05)
    ambi.window(np.zeros(d), np.ones(d))  # then refine everything
    assert ambi.is_fully_refined()
    return ambi.index


# --------------------------------------------------------------------------
# engines under test
# --------------------------------------------------------------------------
class NumpyEngine:
    name = "numpy"

    def __init__(self, index):
        self.index = index

    def window(self, los, his):
        return window_query_batch(self.index, los, his)[0]

    def knn(self, qs, k):
        return knn_query_batch(self.index, qs, k)[0]


class DeviceEngine:
    name = "device"

    def __init__(self, index):
        self.dev = DeviceTable.from_index(index)

    def window(self, los, his):
        return window_query_batch_jax(self.dev, los, his)

    def knn(self, qs, k):
        return knn_query_batch_jax(self.dev, qs, k)


class FusedDeviceEngine:
    """PR-7 second-generation device engine: fused on-device pair packing
    with an optional bf16 compressed-MBB export.  Same id-identity
    contract — the compressed traversal's f32 re-check is what the
    four-way harness pins here."""

    def __init__(self, index, compressed=True):
        self.dev = DeviceTable.from_index(index, compressed=compressed)
        self.name = f"fused[{'bf16' if compressed else 'f32'}]"

    def window(self, los, his):
        return window_query_batch_jax(self.dev, los, his, fused=True)

    def knn(self, qs, k):
        return knn_query_batch_jax(self.dev, qs, k, fused=True)


class ShardedEngine:
    def __init__(self, index, m):
        self.sdev = ShardedDeviceTable.from_index(index, m)
        self.name = f"sharded[m={m}]"

    def window(self, los, his):
        return window_query_batch_sharded(self.sdev, los, his)

    def knn(self, qs, k):
        return knn_query_batch_sharded(self.sdev, qs, k)


class AdaptiveServeEngine:
    """``DeviceQueryServer(adaptive=True)`` booted from the
    single-unrefined-root AMBI state over the same dataset: queries reach
    cold space, get answered host-side with on-demand refinement, and the
    grafts stream to the device as incremental deltas — results must still
    be id-identical to the fully built NumPy engine."""

    name = "adaptive-serve"

    def __init__(self, index, M=250):
        from repro.serve.engine import DeviceQueryServer

        self.ambi = AMBI(np.asarray(index.points, dtype=np.float64), M)
        self.srv = DeviceQueryServer.from_ambi(self.ambi, microbatch=32)

    def window(self, los, his):
        return self.srv.window(los, his)

    def knn(self, qs, k):
        return self.srv.knn(qs, k)


class ServerEngine:
    """``DeviceQueryServer`` over a built static index — the resilience
    plane's front door.  The chaos harness points a seeded ``FaultPlan``
    at it and still demands NumPy-engine parity: bounded faults must be
    absorbed by retries, never surface in results."""

    def __init__(self, index, shards=None, **kw):
        from repro.serve.engine import DeviceQueryServer

        self.srv = DeviceQueryServer.from_index(index, shards=shards, **kw)
        self.name = f"server[m={shards or 1}]"

    def window(self, los, his):
        return self.srv.window(los, his)

    def knn(self, qs, k):
        return self.srv.knn(qs, k)


class FrontendEngine:
    """The async admission/batching frontend over ``DeviceQueryServer``,
    driven deterministically (virtual clock, inline lanes): every query
    goes through submit -> bounded queue -> microbatch close -> dispatch,
    and the served ids must still be id-identical to the NumPy oracle —
    batching and padding are not allowed to change answers."""

    name = "frontend"

    def __init__(self, index, **kw):
        from repro.serve.engine import DeviceQueryServer
        from repro.serve.frontend import Frontend, VirtualClock

        self.srv = DeviceQueryServer.from_index(index, microbatch=32, **kw)
        self.clock = VirtualClock()
        self.fe = Frontend(self.srv, clock=self.clock, queue_bound=4096,
                           batch_max=32, batch_window_s=0.001)

    def _drain(self, reqs):
        self.fe.drain()
        bad = [r for r in reqs if r.status != "ok"]
        assert not bad, f"frontend dropped {len(bad)} requests in parity run"
        return [r.ids for r in reqs]

    def window(self, los, his):
        reqs = [self.fe.submit_window(lo, hi)
                for lo, hi in zip(np.atleast_2d(los), np.atleast_2d(his))]
        return self._drain(reqs)

    def knn(self, qs, k):
        reqs = [self.fe.submit_knn(q, k) for q in np.atleast_2d(qs)]
        return self._drain(reqs)


# --------------------------------------------------------------------------
# streaming-ingest engines (insert/delete/window/knn) + rebuild oracle
# --------------------------------------------------------------------------
class RebuildOracle:
    """The from-scratch authority for streaming parity: after every mutation
    the index is conceptually discarded; each query bulk-loads a fresh FMBI
    over the live points and maps positional ids back to global ids.  What
    the LSM tiers, tombstones and delta uploads must be indistinguishable
    from."""

    name = "rebuild"

    def __init__(self, pts, M=250):
        self.M = M
        self.pts = np.asarray(pts, np.float64).copy()
        self.tomb = np.zeros(len(self.pts), bool)

    def insert(self, new):
        new = np.asarray(new, np.float64)
        ids = np.arange(len(self.pts), len(self.pts) + len(new))
        self.pts = np.concatenate([self.pts, new])
        self.tomb = np.concatenate([self.tomb, np.zeros(len(new), bool)])
        return ids

    def delete(self, ids):
        ids = np.unique(np.asarray(ids, np.int64))
        ids = ids[(ids >= 0) & (ids < len(self.pts))]
        fresh = ids[~self.tomb[ids]]
        self.tomb[fresh] = True
        return len(fresh)

    def _rebuilt(self):
        live = np.flatnonzero(~self.tomb)
        return bulk_load(self.pts[live], self.M, PageStore(self.M)), live

    def window(self, los, his):
        idx, live = self._rebuilt()
        res, _ = window_query_batch(idx, np.atleast_2d(los), np.atleast_2d(his))
        return [np.sort(live[r]) for r in res]

    def knn(self, qs, k):
        idx, live = self._rebuilt()
        qs = np.atleast_2d(qs)
        # over-fetch: the index's own k-boundary tie-break is traversal
        # order, so pull a margin and re-rank by (distance, id) — the
        # streaming contract — before truncating to k
        res, _ = knn_query_batch(idx, qs, min(k + 16, len(live)))
        out = []
        for q, r in zip(qs, res):
            g = live[r]
            d2 = np.sum((self.pts[g] - q) ** 2, axis=1)
            out.append(g[np.lexsort((g, d2))][:k])
        return out


# small thresholds so short tests still cross flush/merge/fusion boundaries
STREAM_KW = dict(delta_threshold=512, delta_index_every=128, size_ratio=3)


class StreamingHostEngine:
    """The host ``StreamingIndex`` itself: delta memtable + size-tiered
    immutable NodeTables, queried with tombstone filtering."""

    name = "stream-host"

    def __init__(self, pts, **kw):
        from repro.core import StreamingIndex

        self.stream = StreamingIndex(
            np.asarray(pts, np.float64), **{**STREAM_KW, **kw}
        )

    def insert(self, pts):
        return self.stream.insert(pts)

    def delete(self, ids):
        return self.stream.delete(ids)

    def window(self, los, his):
        return self.stream.window(np.atleast_2d(los), np.atleast_2d(his))

    def knn(self, qs, k):
        return self.stream.knn(np.atleast_2d(qs), k)


class StreamingServerEngine:
    """``DeviceQueryServer.from_streaming``: the device mirror refreshed
    delta-only while tiers flush, merge and retire underneath it."""

    def __init__(self, pts, shards=None, stream_kw=None, **server_kw):
        from repro.core import StreamingIndex
        from repro.serve.engine import DeviceQueryServer

        self.stream = StreamingIndex(
            np.asarray(pts, np.float64), **{**STREAM_KW, **(stream_kw or {})}
        )
        self.srv = DeviceQueryServer.from_streaming(
            self.stream, microbatch=32, shards=shards, **server_kw
        )
        self.name = f"stream-server[m={shards or 1}]"

    def insert(self, pts):
        return self.srv.insert(pts)

    def delete(self, ids):
        return self.srv.delete(ids)

    def window(self, los, his):
        return self.srv.window(np.atleast_2d(los), np.atleast_2d(his))

    def knn(self, qs, k):
        return self.srv.knn(np.atleast_2d(qs), k)


class OverlayServerEngine:
    """The adaptive server with a streaming overlay: the base dataset keeps
    the cold/hot adaptive path; inserts and deletes land in a lazily created
    ``StreamingIndex`` whose answers are merged into every query."""

    name = "adaptive-overlay"

    def __init__(self, pts, M=250, **kw):
        from repro.core import AMBI
        from repro.serve.engine import DeviceQueryServer

        self.srv = DeviceQueryServer.from_ambi(
            AMBI(np.asarray(pts, np.float64), M), microbatch=32, **kw
        )
        self.srv.OVERLAY_KW = dict(STREAM_KW)

    def insert(self, pts):
        return self.srv.insert(pts)

    def delete(self, ids):
        return self.srv.delete(ids)

    def window(self, los, his):
        return self.srv.window(np.atleast_2d(los), np.atleast_2d(his))

    def knn(self, qs, k):
        return self.srv.knn(np.atleast_2d(qs), k)


def ingest_suite(pts, ms=(3,), adaptive=True):
    """Every streaming-capable engine over the same base dataset; first
    entry is the rebuild oracle."""
    return (
        [RebuildOracle(pts), StreamingHostEngine(pts),
         StreamingServerEngine(pts)]
        + [StreamingServerEngine(pts, shards=m) for m in ms]
        + ([OverlayServerEngine(pts)] if adaptive else [])
    )


def engine_suite(index, ms=(1, 2, 4), adaptive=True):
    """Every engine over one built index; first entry is the NumPy oracle."""
    return (
        [NumpyEngine(index), DeviceEngine(index),
         FusedDeviceEngine(index, compressed=True)]
        + [ShardedEngine(index, m) for m in ms]
        + ([AdaptiveServeEngine(index)] if adaptive else [])
        + [FrontendEngine(index)]
    )


# --------------------------------------------------------------------------
# degraded-mode oracles (completeness-certificate verification)
# --------------------------------------------------------------------------
def shard_owned_ids(sdev, s):
    """Dataset ids owned by shard ``s`` (from its device leaf blocks)."""
    ids = np.asarray(sdev.shards[s].host_ids)
    return set(int(i) for i in ids[ids >= 0])


def assert_degraded_window(pts, lo, hi, got, cert, oracle_ids, dead_owned):
    """A degraded window answer must be exactly the alive-shard subset of
    the oracle answer, and every dropped id must fall inside one of the
    certificate's unanswered-subspace boxes."""
    oracle = set(int(i) for i in oracle_ids)
    got = set(int(i) for i in got)
    if cert.complete:
        assert got == oracle
        return
    assert got == oracle - dead_owned
    dropped = oracle & dead_owned
    p32 = pts.astype(np.float32)
    for i in dropped:
        inside = (
            (cert.missing_lo <= p32[i]) & (p32[i] <= cert.missing_hi)
        ).all(axis=1)
        assert inside.any(), f"dropped id {i} outside every missing box"


def assert_degraded_knn(pts, q, k, got, cert, oracle_ids, dead_owned):
    """A degraded k-NN answer must be the exact k-NN over the alive
    points; ``certified_exact`` additionally means it IS the full oracle
    answer (the dead subspaces were provably excluded)."""
    alive = np.array(
        [i for i in range(len(pts)) if i not in dead_owned], dtype=np.int64
    )
    d2 = np.sum((pts[alive] - q) ** 2, axis=1)
    want = min(k, len(alive))
    expect = alive[np.argsort(d2, kind="stable")[:want]]
    assert np.array_equal(np.asarray(got), expect), "not exact over alive"
    if cert.certified_exact:
        assert np.array_equal(np.asarray(got), np.asarray(oracle_ids))


# --------------------------------------------------------------------------
# parity assertions
# --------------------------------------------------------------------------
def assert_window_parity(engines, los, his):
    """Every engine returns the NumPy engine's id set, per query."""
    los = np.atleast_2d(los)
    his = np.atleast_2d(his)
    ref = engines[0].window(los, his)
    for eng in engines[1:]:
        got = eng.window(los, his)
        assert len(got) == len(ref), eng.name
        for i, (a, b) in enumerate(zip(got, ref)):
            assert np.array_equal(np.sort(a), np.sort(b)), (eng.name, i)
    return ref


def assert_knn_parity(engines, pts, qs, k, ids_exact=True):
    """Every engine returns the NumPy engine's ascending-id sequence.

    ``ids_exact=False`` (tie-heavy workloads): sorted squared-distance
    sequences must match and ids must agree wherever distances are unique.
    """
    qs = np.atleast_2d(qs)
    ref = engines[0].knn(qs, k)
    for eng in engines[1:]:
        got = eng.knn(qs, k)
        assert len(got) == len(ref), eng.name
        for i, (a, b) in enumerate(zip(got, ref)):
            if ids_exact:
                assert np.array_equal(a, b), (eng.name, i)
            else:
                da = np.sort(np.sum((pts[a] - qs[i]) ** 2, axis=1))
                db = np.sort(np.sum((pts[b] - qs[i]) ** 2, axis=1))
                np.testing.assert_array_equal(da, db, err_msg=f"{eng.name} q{i}")
                if len(np.unique(db)) == len(db):
                    assert np.array_equal(np.sort(a), np.sort(b)), (eng.name, i)
    return ref
