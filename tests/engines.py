"""Reusable engine-parity harness (the PR-4 pinning fixture).

Builds the same FMBI / grafted-AMBI tables and runs every query engine the
repo has over them:

  * the NumPy ``NodeTable`` engine (``core/queries.py``) — the
    paper-faithful authority,
  * the single compiled ``DeviceTable`` engine (``core/queries_jax.py``),
  * the m-shard distributed engine (``core/distributed_jax.py``) for each
    requested shard count,

and asserts id-identical results, the same way ``test_flat_queries.py``
pinned the PR-2 flat engine and ``test_queries_jax.py`` pinned the PR-3
device engine.  Windows compare as id sets (result order is unspecified
across engines); k-NN compares ascending id sequences on continuous data
and falls back to distance-sequence equality when the workload carries
exact ties (grid data), mirroring the queries_jax parity contract.

All generated coordinates are float32-representable so the f32 device
engines agree bit-for-bit with the f64 host engine.
"""
import numpy as np

from repro.core import (
    AMBI,
    PageStore,
    bulk_load,
    knn_query_batch,
    window_query_batch,
)
from repro.core.distributed_jax import (
    ShardedDeviceTable,
    knn_query_batch_sharded,
    window_query_batch_sharded,
)
from repro.core.queries_jax import (
    DeviceTable,
    knn_query_batch_jax,
    window_query_batch_jax,
)


# --------------------------------------------------------------------------
# workloads: float32-representable point sets + index builders
# --------------------------------------------------------------------------
def f32_points(n, d, seed, kind="uniform"):
    """Float32-representable coordinates (stored as float64)."""
    rng = np.random.default_rng(seed)
    if kind == "skew":
        pts = rng.random((n, d)) ** 3
    elif kind == "grid":  # heavy duplication, exact f32 arithmetic
        pts = rng.integers(0, 48, (n, d)) / np.float64(64.0)
    else:
        pts = rng.random((n, d))
    return pts.astype(np.float32).astype(np.float64)


def build_fmbi(pts, M=250):
    return bulk_load(pts, M, PageStore(M))


def build_grafted_ambi(pts, M=250):
    """A fully refined AMBI index whose table rows were grafted on demand
    (not level-contiguous — the layout case the device engines must
    normalize)."""
    ambi = AMBI(pts, M)
    d = pts.shape[1]
    rng = np.random.default_rng(0)
    for _ in range(4):  # partial refinement first: interleaved grafts
        c = rng.random(d)
        ambi.window(c - 0.05, c + 0.05)
    ambi.window(np.zeros(d), np.ones(d))  # then refine everything
    assert ambi.is_fully_refined()
    return ambi.index


# --------------------------------------------------------------------------
# engines under test
# --------------------------------------------------------------------------
class NumpyEngine:
    name = "numpy"

    def __init__(self, index):
        self.index = index

    def window(self, los, his):
        return window_query_batch(self.index, los, his)[0]

    def knn(self, qs, k):
        return knn_query_batch(self.index, qs, k)[0]


class DeviceEngine:
    name = "device"

    def __init__(self, index):
        self.dev = DeviceTable.from_index(index)

    def window(self, los, his):
        return window_query_batch_jax(self.dev, los, his)

    def knn(self, qs, k):
        return knn_query_batch_jax(self.dev, qs, k)


class ShardedEngine:
    def __init__(self, index, m):
        self.sdev = ShardedDeviceTable.from_index(index, m)
        self.name = f"sharded[m={m}]"

    def window(self, los, his):
        return window_query_batch_sharded(self.sdev, los, his)

    def knn(self, qs, k):
        return knn_query_batch_sharded(self.sdev, qs, k)


class AdaptiveServeEngine:
    """``DeviceQueryServer(adaptive=True)`` booted from the
    single-unrefined-root AMBI state over the same dataset: queries reach
    cold space, get answered host-side with on-demand refinement, and the
    grafts stream to the device as incremental deltas — results must still
    be id-identical to the fully built NumPy engine."""

    name = "adaptive-serve"

    def __init__(self, index, M=250):
        from repro.serve.engine import DeviceQueryServer

        self.ambi = AMBI(np.asarray(index.points, dtype=np.float64), M)
        self.srv = DeviceQueryServer.from_ambi(self.ambi, microbatch=32)

    def window(self, los, his):
        return self.srv.window(los, his)

    def knn(self, qs, k):
        return self.srv.knn(qs, k)


def engine_suite(index, ms=(1, 2, 4), adaptive=True):
    """Every engine over one built index; first entry is the NumPy oracle."""
    return (
        [NumpyEngine(index), DeviceEngine(index)]
        + [ShardedEngine(index, m) for m in ms]
        + ([AdaptiveServeEngine(index)] if adaptive else [])
    )


# --------------------------------------------------------------------------
# parity assertions
# --------------------------------------------------------------------------
def assert_window_parity(engines, los, his):
    """Every engine returns the NumPy engine's id set, per query."""
    los = np.atleast_2d(los)
    his = np.atleast_2d(his)
    ref = engines[0].window(los, his)
    for eng in engines[1:]:
        got = eng.window(los, his)
        assert len(got) == len(ref), eng.name
        for i, (a, b) in enumerate(zip(got, ref)):
            assert np.array_equal(np.sort(a), np.sort(b)), (eng.name, i)
    return ref


def assert_knn_parity(engines, pts, qs, k, ids_exact=True):
    """Every engine returns the NumPy engine's ascending-id sequence.

    ``ids_exact=False`` (tie-heavy workloads): sorted squared-distance
    sequences must match and ids must agree wherever distances are unique.
    """
    qs = np.atleast_2d(qs)
    ref = engines[0].knn(qs, k)
    for eng in engines[1:]:
        got = eng.knn(qs, k)
        assert len(got) == len(ref), eng.name
        for i, (a, b) in enumerate(zip(got, ref)):
            if ids_exact:
                assert np.array_equal(a, b), (eng.name, i)
            else:
                da = np.sort(np.sum((pts[a] - qs[i]) ** 2, axis=1))
                db = np.sort(np.sum((pts[b] - qs[i]) ** 2, axis=1))
                np.testing.assert_array_equal(da, db, err_msg=f"{eng.name} q{i}")
                if len(np.unique(db)) == len(db):
                    assert np.array_equal(np.sort(a), np.sort(b)), (eng.name, i)
    return ref
