"""Chaos parity (PR-6): the engine harness under a seeded FaultPlan.

Every run is replayable (the plan schedule is a pure function of its
seed), and the contract is two-sided:

  * **bounded** faults — capped so the retry policy provably outlasts
    them — must be invisible: results stay id-identical to the NumPy
    oracle (``engines.py`` parity);
  * **unbounded** faults (a dead shard) must surface as *honest*
    degradation: partial results carrying a completeness certificate
    that verifies against the oracle restricted to the alive shards,
    with ``certified_exact`` k-NN answers exactly matching the full
    oracle.  Repair then restores full parity.
"""
import os

import numpy as np
import pytest

from repro.core.distributed_jax import (
    ShardedDeviceTable,
    knn_query_batch_sharded,
    window_query_batch_sharded,
)
from repro.serve.engine import DeviceQueryServer
from repro.serve.faults import FaultPlan, FaultRule
from repro.serve.resilience import RetryPolicy

from engines import (
    AdaptiveServeEngine,
    NumpyEngine,
    ServerEngine,
    assert_degraded_knn,
    assert_degraded_window,
    assert_knn_parity,
    assert_window_parity,
    build_fmbi,
    f32_points,
    shard_owned_ids,
)

# pinned in CI (REPRO_FAULT_SEED): the whole chaos run replays the exact
# same fault schedule; override locally to explore other schedules
CHAOS_SEED = int(os.environ.get("REPRO_FAULT_SEED", "1337"))


@pytest.fixture(scope="module")
def setup():
    pts = f32_points(900, 2, seed=21)
    index = build_fmbi(pts, M=64)
    rng = np.random.default_rng(4)
    c = rng.random((16, 2))
    los = np.clip(c - 0.15, 0, 1)
    his = np.clip(c + 0.15, 0, 1)
    qs = rng.random((16, 2))
    return pts, index, los, his, qs


def _no_sleep_retry(attempts):
    return RetryPolicy(max_attempts=attempts, sleep=lambda s: None)


def test_chaos_parity_under_bounded_storm(setup):
    """tests/engines.py parity with a seeded storm across the serving
    fault points.  max_fires(3) < max_attempts(5) guarantees retries
    outlast the storm even if every fire lands in one op's attempts."""
    pts, index, los, his, qs = setup
    storms = []

    def server(shards):
        plan = FaultPlan.storm(
            ("shard_dispatch",), 0.4, seed=CHAOS_SEED,
            max_fires_per_point=3,
        )
        storms.append(plan)
        return ServerEngine(
            index, shards=shards, microbatch=8, fault_plan=plan,
            retry=_no_sleep_retry(5),
        )

    engines = [NumpyEngine(index), server(None), server(2), server(4)]
    assert_window_parity(engines, los, his)
    assert_knn_parity(engines, pts, qs, 5)
    assert sum(p.total_fires for p in storms) > 0  # chaos actually hit
    assert sum(e.srv.stats.retries for e in engines[1:]) > 0
    assert all(e.srv.stats.degraded_queries == 0 for e in engines[1:])


def test_chaos_parity_adaptive_under_storm(setup):
    """The adaptive serving loop under a storm spanning device dispatch,
    the host cold path, its page store, and the delta upload.  host_refine
    and pagestore_read burn the same retry loop, so the attempt budget
    covers their combined cap; apply_delta exhaustion is absorbed by
    design (device stale, host authoritative)."""
    pts, index, los, his, qs = setup
    oracle = NumpyEngine(index)
    plan = FaultPlan.storm(
        ("shard_dispatch", "host_refine", "pagestore_read", "apply_delta"),
        0.3, seed=CHAOS_SEED, max_fires_per_point=2,
    )
    eng = AdaptiveServeEngine(index)
    eng.srv.fault_plan = plan
    eng.srv.retry = _no_sleep_retry(6)
    eng.srv.ambi.store.fault_hook = plan.pagestore_hook()
    assert_window_parity([oracle, eng], los, his)
    assert_knn_parity([oracle, eng], pts, qs, 5)
    assert plan.total_fires > 0
    assert eng.srv.stats.retries > 0


def test_chaos_adaptive_serves_through_device_outage(setup):
    """Graceful degradation: with the device permanently dead, the
    adaptive server reroutes every query down the exact host path —
    full parity, no degraded certificates, fallbacks accounted."""
    pts, index, los, his, qs = setup
    oracle = NumpyEngine(index)
    plan = FaultPlan([FaultRule("shard_dispatch", rate=1.0)],
                     seed=CHAOS_SEED)
    eng = AdaptiveServeEngine(index)
    eng.srv.fault_plan = plan
    eng.srv.retry = _no_sleep_retry(2)
    eng.srv.breaker_threshold = 1
    assert_window_parity([oracle, eng], los, his)
    assert_knn_parity([oracle, eng], pts, qs, 5)
    assert eng.srv.stats.host_fallbacks > 0
    assert eng.srv.stats.degraded_queries == 0  # host answers are exact
    res, certs = eng.srv.window(los, his, return_certs=True)
    assert all(c.complete for c in certs)


@pytest.fixture(scope="module")
def dead_shard_setup(setup):
    pts, index, los, his, qs = setup
    dead = 2
    plan = FaultPlan(
        [FaultRule("shard_dispatch", rate=1.0, match={"shard": dead})],
        seed=CHAOS_SEED,
    )
    srv = DeviceQueryServer.from_index(
        index, shards=4, microbatch=8, fault_plan=plan,
        retry=_no_sleep_retry(2), breaker_threshold=1,
        breaker_cooldown_s=1e9,
    )
    owned = shard_owned_ids(srv.sdev, dead)
    assert owned  # the dead shard really owns part of the dataset
    return pts, index, srv, plan, dead, owned


def test_chaos_dead_shard_window_certificates(setup, dead_shard_setup):
    pts, index, srv, plan, dead, owned = dead_shard_setup
    _, _, los, his, qs = setup
    oracle = NumpyEngine(index)
    ref = oracle.window(los, his)
    got, certs = srv.window(los, his, return_certs=True)
    n_degraded = 0
    for i in range(len(los)):
        cert = certs[i]
        if not cert.complete:
            n_degraded += 1
            assert cert.missing_shards == (dead,)
            assert not cert.certified_exact  # windows never certify holes
        assert_degraded_window(
            pts, los[i], his[i], got[i], cert, ref[i], owned
        )
    # the workload must actually exercise both modes
    assert 0 < n_degraded < len(los)
    assert srv.stats.degraded_queries == n_degraded


def test_chaos_dead_shard_knn_certificates(setup, dead_shard_setup):
    pts, index, srv, plan, dead, owned = dead_shard_setup
    _, _, los, his, qs = setup
    k = 5
    oracle = NumpyEngine(index)
    ref = oracle.knn(qs, k)
    got, certs = srv.knn(qs, k, return_certs=True)
    n_exact = n_partial = 0
    for i in range(len(qs)):
        cert = certs[i]
        if cert.certified_exact:
            n_exact += 1
        elif not cert.complete:
            n_partial += 1
            assert cert.missing_shards == (dead,)
        assert_degraded_knn(pts, qs[i], k, got[i], cert, ref[i], owned)
    # far queries certify exact (pruning radius clears the dead shard),
    # near ones honestly report the unanswerable subspace
    assert n_exact > 0 and n_partial > 0


def test_chaos_repair_restores_full_parity(setup, dead_shard_setup):
    pts, index, srv, plan, dead, owned = dead_shard_setup
    _, _, los, his, qs = setup
    oracle = NumpyEngine(index)
    assert srv.breakers[dead].state == "open"
    refreshes_before = srv.stats.shard_refreshes
    plan.disarm()  # the operator fixed the fault; now repair the shard
    assert srv.repair() == [dead]
    assert srv.stats.shard_refreshes == refreshes_before + 1
    assert srv.breakers[dead].state == "closed"
    got, certs = srv.window(los, his, return_certs=True)
    assert all(c.complete for c in certs)
    ref = oracle.window(los, his)
    for a, b in zip(got, ref):
        assert np.array_equal(np.sort(a), np.sort(b))
    for a, b in zip(srv.knn(qs, 5), oracle.knn(qs, 5)):
        assert np.array_equal(a, b)


def test_chaos_protocol_level_degraded_queries(setup):
    """The sharded protocols themselves (no server) honour the runner /
    return_certs contract — the unit under the integration above."""
    from repro.core.distributed_jax import ShardUnavailable

    pts, index, los, his, qs = setup
    sdev = ShardedDeviceTable.from_index(index, 4)
    dead = 1
    owned = shard_owned_ids(sdev, dead)

    def runner(s, thunk):
        if s == dead:
            raise ShardUnavailable(s, "injected")
        return thunk()

    # without certs, the outage must raise — silent partials are a bug
    with pytest.raises(ShardUnavailable):
        window_query_batch_sharded(sdev, los, his, runner=runner)
    ref_w = window_query_batch_sharded(sdev, los, his)  # healthy oracle
    got, certs = window_query_batch_sharded(
        sdev, los, his, runner=runner, return_certs=True
    )
    for i in range(len(los)):
        assert_degraded_window(
            pts, los[i], his[i], got[i], certs[i], ref_w[i], owned
        )
    ref_k = knn_query_batch_sharded(sdev, qs, 5)
    got, certs = knn_query_batch_sharded(
        sdev, qs, 5, runner=runner, return_certs=True
    )
    for i in range(len(qs)):
        assert_degraded_knn(pts, qs[i], 5, got[i], certs[i], ref_k[i], owned)
