import numpy as np
import pytest

from repro.core import AMBI, PageStore, bulk_load, knn_oracle, window_oracle
from repro.core.datasets import osm_like


@pytest.fixture()
def data():
    return osm_like(220_000, seed=11)


def test_first_query_builds_and_answers(data):
    a = AMBI(data, 300)
    lo, hi = np.array([0.6, 0.6]), np.array([0.66, 0.66])
    res, io = a.window(lo, hi)
    ref = window_oracle(data, lo, hi)
    assert sorted(res.tolist()) == sorted(ref.tolist())
    assert io.reads > 0 and io.writes > 0  # the build happened
    assert not a.is_fully_refined()        # ... but only partially


def test_focused_workload_stays_partial_and_correct(data):
    a = AMBI(data, 300)
    rng = np.random.default_rng(0)
    for _ in range(30):
        c = rng.random(2) * 0.08 + np.array([0.55, 0.55])
        res, _ = a.window(c - 0.02, c + 0.02)
        ref = window_oracle(data, c - 0.02, c + 0.02)
        assert sorted(res.tolist()) == sorted(ref.tolist())
    assert not a.is_fully_refined()


def test_knn_correct(data):
    a = AMBI(data, 300)
    rng = np.random.default_rng(1)
    for k in (4, 32):
        q = rng.random(2)
        res, _ = a.knn(q, k)
        ref = knn_oracle(data, q, k)
        assert np.allclose(
            np.sort(np.sum((data[res] - q) ** 2, axis=1)),
            np.sort(np.sum((data[ref] - q) ** 2, axis=1)),
        )


def test_covering_queries_converge_to_full_index(data):
    a = AMBI(data, 300)
    for x in np.linspace(0.05, 0.95, 8):
        for y in np.linspace(0.05, 0.95, 8):
            a.window(np.array([x - 0.08, y - 0.08]),
                     np.array([x + 0.08, y + 0.08]))
    assert a.is_fully_refined()
    # converged index answers exactly
    rng = np.random.default_rng(2)
    for _ in range(10):
        c = rng.random(2)
        res, _ = a.window(c - 0.03, c + 0.03)
        ref = window_oracle(data, c - 0.03, c + 0.03)
        assert sorted(res.tolist()) == sorted(ref.tolist())


def test_adaptive_cheaper_than_full_build_for_few_queries(data):
    """Paper Fig 8: combined build+query cost of AMBI beats FMBI's build
    cost alone when the workload is small and focused."""
    a = AMBI(data, 300)
    cum = 0
    rng = np.random.default_rng(3)
    for _ in range(20):
        c = rng.random(2) * 0.05 + 0.6
        _, io = a.window(c - 0.02, c + 0.02)
        cum += io.total
    store = PageStore(300)
    bulk_load(data, 300, store)
    assert cum < store.stats.total


def test_all_points_recoverable_after_partial_refinement(data):
    a = AMBI(data, 300)
    a.window(np.array([0.1, 0.1]), np.array([0.2, 0.2]))
    res, _ = a.window(np.array([-1.0, -1.0]), np.array([2.0, 2.0]))
    assert len(res) == len(data)
