"""NodeTable invariants (hypothesis + fixed seeds), snapshots, merges, grafts."""
import numpy as np
import pytest

from repro.core import AMBI, Index, NodeTable, PageStore, bulk_load
from repro.core.datasets import osm_like
from repro.core.distributed import parallel_bulk_load
from repro.core.nodetable import ragged_ranges
from repro.core.pagestore import leaf_capacity
from repro.core.queries import knn_query, window_oracle, window_query

try:  # optional dev dependency (see requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _make_points(kind: str, n: int, d: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        pts = rng.random((n, d))
    elif kind == "gauss":
        pts = rng.normal(0.5, 0.2, (n, d))
    elif kind == "skew":
        pts = rng.random((n, d)) ** 3
    else:  # "dup": heavy coordinate duplication (degenerate medians)
        pts = rng.integers(0, 12, (n, d)).astype(np.float64) / 12.0
    return pts.astype(np.float64)


def _sibling_leaf_overlap(table: NodeTable) -> float:
    """Total pairwise overlap volume between the leaf children of every
    branch (FMBI's zero-overlap invariant, any dimensionality)."""
    total = 0.0
    for r in np.flatnonzero(table.child_count > 0):
        kids = np.fromiter(table.children_of(r), dtype=np.int64)
        leaf_kids = kids[table.is_leaf_row(kids)]
        if len(leaf_kids) < 2:
            continue
        los, his = table.mbb_lo[leaf_kids], table.mbb_hi[leaf_kids]
        for i in range(len(leaf_kids) - 1):
            lo = np.maximum(los[i + 1 :], los[i])
            hi = np.minimum(his[i + 1 :], his[i])
            ext = np.clip(hi - lo, 0.0, None)
            total += float(np.prod(ext, axis=1).sum())
    return total


def _assert_fullness_at_paper_bound(pts: np.ndarray) -> None:
    """In-buffer refinement packs exactly ceil(n / C_L) leaves — the paper's
    full-but-last-page guarantee — so fill sits at the arithmetic optimum."""
    idx = bulk_load(pts, 250)  # small n: single Algorithm-1 refine
    t = idx.table
    c_l = leaf_capacity(pts.shape[1])
    n_leaves = len(t.leaf_rows())
    assert n_leaves == -(-len(pts) // c_l)
    assert len(pts) / (n_leaves * c_l) >= len(pts) / (len(pts) + c_l) - 1e-12
    assert np.all(t.leaf_count[t.leaf_rows()] <= c_l)


# --------------------------------------------------------------------------
# fixed-seed invariant sweep (always runs, hypothesis or not)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["uniform", "gauss", "skew", "dup"])
@pytest.mark.parametrize("d", [2, 4])
def test_table_invariants_fixed(kind, d):
    pts = _make_points(kind, 3000, d, seed=7)
    idx = bulk_load(pts, 250)
    idx.table.check_invariants(len(pts))
    if kind != "dup":  # duplicated coordinates can tie on the cut
        assert _sibling_leaf_overlap(idx.table) < 1e-9
    _assert_fullness_at_paper_bound(pts)


if HAVE_HYPOTHESIS:

    @st.composite
    def point_sets(draw, min_n=400, max_n=4000, d_max=4, continuous_only=False):
        n = draw(st.integers(min_value=min_n, max_value=max_n))
        d = draw(st.integers(min_value=2, max_value=d_max))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        kinds = ["uniform", "gauss", "skew"]
        if not continuous_only:
            kinds.append("dup")
        return _make_points(draw(st.sampled_from(kinds)), n, d, seed)

    @given(point_sets())
    @settings(max_examples=12, deadline=None)
    def test_table_invariants(pts):
        """CSR child ranges partition the rows; live perm segments partition
        the dataset; parent boxes contain child boxes."""
        idx = bulk_load(pts, 250)
        idx.table.check_invariants(len(pts))

    @given(point_sets(continuous_only=True))
    @settings(max_examples=10, deadline=None)
    def test_zero_node_overlap(pts):
        """FMBI's median splits produce zero overlap between sibling leaves
        (continuous coordinates; duplicates can tie on the cut)."""
        idx = bulk_load(pts, 250)
        assert _sibling_leaf_overlap(idx.table) < 1e-9

    @given(point_sets())
    @settings(max_examples=10, deadline=None)
    def test_leaf_fullness_at_paper_bound(pts):
        _assert_fullness_at_paper_bound(pts)


def test_invariants_hold_on_full_five_step_build():
    pts = osm_like(120_000, seed=3)
    idx = bulk_load(pts, 205)
    idx.table.check_invariants(len(pts))
    t = idx.table
    assert float((t.leaf_count[t.leaf_rows()]).sum()) / (
        len(t.leaf_rows()) * idx.leaf_cap
    ) > 0.6


def test_ambi_graft_keeps_invariants():
    pts = osm_like(60_000, seed=11)
    a = AMBI(pts, 300)
    rng = np.random.default_rng(0)
    for _ in range(12):
        c = rng.random(2)
        a.window(c - 0.05, c + 0.05)
        a.index.table.check_invariants(len(pts))
    # dead perm segments accumulate (grafts append), live ones stay exact
    assert a.index.table.n_perm >= len(pts)


# --------------------------------------------------------------------------
# snapshot round-trip (acceptance: 100k points, identical results + IOStats)
# --------------------------------------------------------------------------
def test_save_load_roundtrip_100k(tmp_path):
    pts = osm_like(100_000, seed=21)
    M = 250
    idx = bulk_load(pts, M, PageStore(M))
    path = tmp_path / "fmbi_100k.npz"
    idx.save(path)

    loaded = Index.load(path)
    assert loaded.store.buffer.capacity == M
    assert loaded.store.allocated_pages == idx.store.allocated_pages
    np.testing.assert_array_equal(loaded.points, pts)

    # cold-for-cold comparison: the loaded store starts empty, so clear the
    # builder's buffer too, then drive both through one query stream
    idx.store.buffer.clear()
    rng = np.random.default_rng(1)
    for i in range(25):
        if i % 2 == 0:
            c = rng.random(2)
            r1, io1 = window_query(idx, c - 0.03, c + 0.03)
            r2, io2 = window_query(loaded, c - 0.03, c + 0.03)
            np.testing.assert_array_equal(r1, r2)
            np.testing.assert_array_equal(
                np.sort(r1), window_oracle(pts, c - 0.03, c + 0.03)
            )
        else:
            q = rng.random(2)
            r1, io1 = knn_query(idx, q, 16)
            r2, io2 = knn_query(loaded, q, 16)
            np.testing.assert_array_equal(r1, r2)
        assert (io1.reads, io1.writes) == (io2.reads, io2.writes)


def test_snapshot_without_points_needs_explicit_points(tmp_path):
    pts = osm_like(3_000, seed=2)
    idx = bulk_load(pts, 250)
    path = tmp_path / "lean.npz"
    idx.save(path, include_points=False)
    with pytest.raises(ValueError):
        Index.load(path)
    loaded = Index.load(path, points=pts)
    c = np.array([0.4, 0.4])
    r, _ = window_query(loaded, c - 0.1, c + 0.1)
    assert sorted(r.tolist()) == sorted(window_oracle(pts, c - 0.1, c + 0.1).tolist())


# --------------------------------------------------------------------------
# distributed: per-server tables merge into one global snapshot
# --------------------------------------------------------------------------
def test_merged_distributed_table_answers_globally():
    pts = osm_like(60_000, seed=31)
    build = parallel_bulk_load(pts, m=4, buffer_pages=600)
    merged = build.merged_table()
    merged.check_invariants(len(pts))
    assert merged.child_count[0] == 4
    gidx = build.merged_index(pts, buffer_pages=300)
    rng = np.random.default_rng(3)
    for _ in range(8):
        c = rng.random(2)
        res, io = window_query(gidx, c - 0.04, c + 0.04)
        ref = window_oracle(pts, c - 0.04, c + 0.04)
        assert sorted(res.tolist()) == sorted(ref.tolist())
        assert io.total >= 0


def test_ragged_ranges():
    np.testing.assert_array_equal(
        ragged_ranges(np.array([5, 0, 9]), np.array([2, 3, 0])),
        np.array([5, 6, 0, 1, 2]),
    )
    assert len(ragged_ranges(np.zeros(0), np.zeros(0))) == 0


# --------------------------------------------------------------------------
# amortized growth (PR-9 satellite): appends double, never copy-per-append
# --------------------------------------------------------------------------
def test_append_growth_is_amortized_doubling():
    """Sustained appends (the streaming mirror's attach path) must grow the
    backing arrays geometrically: O(log) reallocations and O(n) total rows
    copied, never a reallocation-plus-full-copy per append."""
    import math

    t = bulk_load(_make_points("uniform", 2000, 2, 0), 250).table
    src = bulk_load(_make_points("uniform", 400, 2, 1), 250).table
    r0, c0 = t.node_reallocs, t.node_rows_copied
    pr0, pc0 = t.perm_reallocs, t.perm_elems_copied
    for _ in range(300):
        t.append_subtree(src)
    assert t.node_reallocs - r0 <= math.ceil(math.log2(t.n_nodes)) + 2
    assert t.node_rows_copied - c0 <= 4 * t.n_nodes
    assert t.perm_reallocs - pr0 <= math.ceil(math.log2(t.n_perm)) + 2
    assert t.perm_elems_copied - pc0 <= 4 * t.n_perm


def test_compact_leaves_append_headroom():
    """Compaction keeps slack past the live rows, so the append that follows
    a compact does not immediately reallocate (the flush-compact-flush
    ping-pong the streaming delta would otherwise hit)."""
    pts = osm_like(30_000, seed=5)
    a = AMBI(pts, 300)
    a.window(np.zeros(2), np.ones(2))  # refine everything (graft appends)
    t = a.index.table
    t.compact()
    reallocs = t.node_reallocs
    src = bulk_load(_make_points("uniform", 300, 2, 2), 250).table
    t.append_subtree(src)
    assert t.node_reallocs == reallocs, "append right after compact realloced"
