"""Adaptive device serving: AMBI behind DeviceQueryServer.

The acceptance criterion: ``DeviceQueryServer(adaptive=True)`` boots from
the single-unrefined-root AMBI state and serves a pinned hotspot stream
with window/k-NN results id-identical to the host AMBI engine, while the
upload counters prove each graft re-uploads only its delta — no full
``DeviceTable`` re-export after the initial boot.

Also here: the partial device layout's cold mask, ``apply_delta`` vs a
fresh full export, targeted ``ShardedDeviceTable.refresh``, the
``NodeTable.compact`` vacuum under graft churn (hypothesis + fixed
seeds), the DeviceTable pytree round-trip regression, the
RetrievalServer LRU-policy regression, and the explicit query-context
refiner contract.
"""
import jax
import numpy as np
import pytest

from repro.core import AMBI, PageStore, bulk_load, knn_oracle, window_oracle
from repro.core import queries_jax as QJ
from repro.core.geometry import boxes_intersect_windows
from repro.core.queries import knn_query_batch, window_query_batch
from repro.core.queries_jax import (
    DeviceTable,
    knn_query_batch_jax,
    window_query_batch_jax,
)
from repro.serve.engine import DeviceQueryServer, RetrievalServer

try:  # optional dev dependency (see requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _f32_points(n, d, seed, kind="uniform"):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, d)) ** (3 if kind == "skew" else 1)
    return pts.astype(np.float32).astype(np.float64)


def _hotspot_stream(d, steps, per_step, seed):
    """Pinned stream alternating two hotspots (the workload AMBI's partial
    index exists for: most of the space is never touched)."""
    rng = np.random.default_rng(seed)
    centers = [np.full(d, 0.3), np.full(d, 0.7)]
    out = []
    for s in range(steps):
        c = centers[s % 2] + rng.random((per_step, d)) * 0.08
        out.append(c.astype(np.float32).astype(np.float64))
    return out


# --------------------------------------------------------------------------
# acceptance: unrefined-root boot, host parity, delta-only uploads
# --------------------------------------------------------------------------
def test_adaptive_server_hotspot_stream_parity_and_delta_uploads():
    pts = _f32_points(100_000, 2, 0)
    M = 120  # 294 data pages >> M: the root is dense, refinement is real
    host = AMBI(pts, M)           # the reference engine, driven identically
    ambi = AMBI(pts, M)
    QJ.reset_upload_stats()  # the module-level default sink, for the
    # no-leak assertion at the end — the server's own counters are fresh
    srv = DeviceQueryServer.from_ambi(ambi, microbatch=8)
    assert srv.upload_stats["full_exports"] == 1  # the boot
    assert srv.dev.n_leaves == 0 and srv.dev.n_cold == 1

    for step, batch in enumerate(_hotspot_stream(2, 10, 8, 1)):
        los, his = batch - 0.02, batch + 0.02
        got_w = srv.window(los, his)
        got_k = srv.knn(batch, 8)
        for i in range(len(batch)):
            want_w, _ = host.window(los[i], his[i])
            assert np.array_equal(np.sort(got_w[i]), np.sort(want_w)), (
                step, i)
            want_k, _ = host.knn(batch[i], 8)
            assert np.array_equal(got_k[i], want_k), (step, i)

    # the workload is focused: the index stays partial, serving went hot
    assert not ambi.is_fully_refined()
    assert srv.stats.cold_queries > 0 and srv.stats.hot_queries > 0
    assert srv.stats.grafts > 0 and srv.stats.delta_refreshes > 0
    # upload accounting: one boot export, every graft shipped only its
    # delta — each leaf block crossed the host/device boundary exactly once
    assert srv.upload_stats["full_exports"] == 1
    assert srv.upload_stats["delta_refreshes"] == srv.stats.delta_refreshes
    assert srv.upload_stats["uploaded_leaf_blocks"] == srv.dev.n_leaves
    assert srv.upload_stats["uploaded_points"] == srv.dev.n_points
    # instance-scoped counters: this server's uploads never leaked into
    # the module-level default sink
    assert QJ.UPLOAD_STATS["full_exports"] == 0
    ambi.table.check_invariants(len(pts))

    # steady state: replaying the pinned hotspots is all-device, no I/O
    cold_before = srv.stats.cold_queries
    io_before = ambi.store.stats.total
    for batch in _hotspot_stream(2, 4, 8, 1)[:2]:
        srv.window(batch - 0.02, batch + 0.02)
        srv.knn(batch, 8)
    assert srv.stats.cold_queries == cold_before
    assert ambi.store.stats.total == io_before


def test_adaptive_server_converges_to_refined_and_stays_device_only():
    pts = _f32_points(40_000, 2, 3)
    ambi = AMBI(pts, 80)
    srv = DeviceQueryServer.from_ambi(ambi, microbatch=4)
    res = srv.window(np.zeros((1, 2)), np.ones((1, 2)))
    assert len(res[0]) == len(pts)
    assert ambi.is_fully_refined()
    assert srv.dev.n_cold == 0
    idx = bulk_load(pts, 250, PageStore(250))
    qs = _f32_points(8, 2, 4)
    want, _ = knn_query_batch(idx, qs, 16)
    got = srv.knn(qs, 16)
    for a, b in zip(got, want):
        assert np.array_equal(a, b)
    assert srv.stats.cold_queries == 1  # only the covering window


# --------------------------------------------------------------------------
# partial layout: the frontier's cold mask
# --------------------------------------------------------------------------
def _partially_refined(pts, M=120, seed=5):
    ambi = AMBI(pts, M)
    rng = np.random.default_rng(seed)
    for _ in range(3):
        c = rng.random(2) * 0.2 + 0.4
        ambi.window(c - 0.03, c + 0.03)
    assert not ambi.is_fully_refined()
    return ambi


def test_partial_layout_cold_mask_matches_host_geometry():
    pts = _f32_points(60_000, 2, 5)
    ambi = _partially_refined(pts)
    t = ambi.table
    dev = DeviceTable.from_table(t, pts, partial=True)
    assert dev.n_cold == int(t.unrefined.sum()) > 0
    rng = np.random.default_rng(6)
    c = rng.random((32, 2)).astype(np.float32).astype(np.float64)
    los, his = c - 0.04, c + 0.04
    res, cold = window_query_batch_jax(dev, los, his, return_cold=True)
    assert cold.shape == (32, dev.n_cold)
    # reaching an unrefined row == intersecting its MBB (downward-closed
    # hit sets), so the mask equals the host-side box test
    unref = np.flatnonzero(t.unrefined)
    assert np.array_equal(dev.cold_rows, unref)  # cold columns = row order
    want = boxes_intersect_windows(
        t.mbb_lo[unref],
        t.mbb_hi[unref],
        los.astype(np.float32).astype(np.float64),
        his.astype(np.float32).astype(np.float64),
    )
    assert np.array_equal(cold, want)
    # hot-query device results equal the refined part of the oracle
    cold_rows_pts = set()
    for r in unref:
        cold_rows_pts.update(t.point_rows(r).tolist())
    for i in np.flatnonzero(~cold.any(axis=1)):
        want_ids = window_oracle(pts, los[i], his[i])
        assert not (set(want_ids.tolist()) & cold_rows_pts)
        assert np.array_equal(np.sort(res[i]), np.sort(want_ids))


def test_device_layout_still_rejects_unrefined_without_partial():
    pts = _f32_points(60_000, 2, 5)
    ambi = _partially_refined(pts)
    with pytest.raises(ValueError, match="partial"):
        ambi.table.device_layout(pts)


# --------------------------------------------------------------------------
# apply_delta: incremental refresh == fresh full export
# --------------------------------------------------------------------------
def test_apply_delta_matches_full_export_and_uploads_only_new_leaves():
    pts = _f32_points(60_000, 2, 7)
    ambi = AMBI(pts, 120)
    dev = DeviceTable.from_table(ambi.table, pts, partial=True)
    rng = np.random.default_rng(8)
    for step in range(4):
        c = rng.random(2) * 0.6 + 0.2
        ambi.window(c - 0.04, c + 0.04)  # grafts
        QJ.reset_upload_stats()
        n_before = dev.n_leaves
        dev = dev.apply_delta(ambi.table, pts)
        delta_blocks = QJ.UPLOAD_STATS["uploaded_leaf_blocks"]
        fresh = DeviceTable.from_table(ambi.table, pts, partial=True)
        assert QJ.UPLOAD_STATS["delta_refreshes"] == 1
        # the delta shipped exactly the new leaves — strictly fewer than a
        # full export once there is a retained prefix
        assert delta_blocks == fresh.n_leaves - n_before
        if step > 0:
            assert delta_blocks < fresh.n_leaves, step
        assert dev.n_leaves == fresh.n_leaves
        assert dev.n_cold == fresh.n_cold
        assert dev.n_points == fresh.n_points
        # same leaf content (slot order may differ) ...
        def key(d):
            ids = np.asarray(d.leaf_ids)
            return sorted(tuple(sorted(row[row >= 0])) for row in ids)
        assert key(dev) == key(fresh)
        # ... and identical query behaviour
        qs = (rng.random((16, 2)) * 0.8 + 0.1)
        qs = qs.astype(np.float32).astype(np.float64)
        rw, cw = window_query_batch_jax(dev, qs - 0.03, qs + 0.03,
                                        return_cold=True)
        fw, fcold = window_query_batch_jax(fresh, qs - 0.03, qs + 0.03,
                                           return_cold=True)
        for a, b in zip(rw, fw):
            assert np.array_equal(np.sort(a), np.sort(b))
        assert np.array_equal(cw.any(axis=1), fcold.any(axis=1))
        rk = knn_query_batch_jax(dev, qs, 8)
        fk = knn_query_batch_jax(fresh, qs, 8)
        for a, b in zip(rk, fk):
            assert np.array_equal(a, b)


def test_apply_delta_requires_scaffolding_after_pytree_roundtrip():
    pts = _f32_points(20_000, 2, 9)
    idx = bulk_load(pts, 250, PageStore(250))
    dev = DeviceTable.from_index(idx)
    leaves, treedef = jax.tree_util.tree_flatten(dev)
    dev2 = jax.tree_util.tree_unflatten(treedef, leaves)
    with pytest.raises(ValueError, match="scaffolding"):
        dev2.apply_delta(idx.table, pts)


# --------------------------------------------------------------------------
# sharded adaptive: refresh touches only changed shards
# --------------------------------------------------------------------------
def test_sharded_adaptive_refreshes_only_changed_shards():
    pts = _f32_points(100_000, 2, 10)
    host = AMBI(pts, 120)
    ambi = AMBI(pts, 120)
    for a in (host, ambi):  # give the root children so the plan can split
        a.window(np.full(2, 0.4), np.full(2, 0.45))
    srv = DeviceQueryServer.from_ambi(ambi, microbatch=8, shards=4)
    m = srv.sdev.m
    boot = srv.upload_stats["full_exports"]
    assert boot == m
    rng = np.random.default_rng(11)
    for step in range(4):
        c = rng.random((8, 2)) * 0.3 + 0.3
        c = c.astype(np.float32).astype(np.float64)
        got = srv.window(c - 0.02, c + 0.02)
        for i in range(8):
            want, _ = host.window(c[i] - 0.02, c[i] + 0.02)
            assert np.array_equal(np.sort(got[i]), np.sort(want)), (step, i)
        gk = srv.knn(c, 8)
        for i in range(8):
            wk, _ = host.knn(c[i], 8)
            assert np.array_equal(gk[i], wk), (step, i)
    # every post-boot export was a targeted shard refresh, and the focused
    # stream touched a strict subset of the shards per refresh round
    extra = srv.upload_stats["full_exports"] - boot
    assert extra == srv.stats.shard_refreshes > 0
    assert extra < m * srv.stats.microbatches
    ambi.table.check_invariants(len(pts))


def test_sharded_adaptive_unrefined_root_boot_replans_to_m_shards():
    """Booting sharded serving from the single-unrefined-root state starts
    with the only possible plan (one whole-table shard) and must *re-plan*
    to the requested shard count once grafts grow the tree — not keep
    full-re-exporting the degenerate shard forever."""
    pts = _f32_points(80_000, 2, 20)
    host = AMBI(pts, 120)
    ambi = AMBI(pts, 120)
    srv = DeviceQueryServer.from_ambi(ambi, microbatch=8, shards=3)
    assert srv.sdev.m == 1  # nothing to cut yet
    rng = np.random.default_rng(21)
    for step in range(4):
        c = (rng.random((8, 2)) * 0.3 + 0.3).astype(np.float32)
        c = c.astype(np.float64)
        got = srv.window(c - 0.02, c + 0.02)
        for i in range(8):
            want, _ = host.window(c[i] - 0.02, c[i] + 0.02)
            assert np.array_equal(np.sort(got[i]), np.sort(want)), (step, i)
    assert srv.sdev.m == 3 and srv.stats.shards == 3
    # post-re-plan refreshes are targeted: total exports = degenerate boot
    # + one m-shard re-plan + the per-changed-shard refreshes after it
    assert srv.upload_stats["full_exports"] == (
        1 + srv.sdev.m + (srv.stats.shard_refreshes - srv.sdev.m)
    )


# --------------------------------------------------------------------------
# compact: vacuum under graft churn (satellite 5)
# --------------------------------------------------------------------------
def _churn_once(seed: int, ops: list[int]) -> None:
    pts = _f32_points(12_000, 2, seed)
    M = 24  # 36 data pages > M: dense root, real adaptive builds
    ambi = AMBI(pts, M)
    fresh = bulk_load(pts, 250, PageStore(250))  # id-parity reference
    rng = np.random.default_rng(seed + 100)
    for op in ops:
        if op == 0:
            c = rng.random(2) * 0.8 + 0.1
            lo, hi = c - 0.05, c + 0.05
            got, _ = ambi.window(lo, hi)
            want, _ = window_query_batch(fresh, lo[None], hi[None])
            assert np.array_equal(np.sort(got), np.sort(want[0]))
        elif op == 1:
            q = rng.random(2).astype(np.float32).astype(np.float64)
            k = int(rng.integers(1, 20))
            got, _ = ambi.knn(q, k)
            want, _ = knn_query_batch(fresh, q[None], k)
            da = np.sum((pts[got] - q) ** 2, axis=1)
            db = np.sum((pts[want[0]] - q) ** 2, axis=1)
            np.testing.assert_array_equal(da, db)
            if len(np.unique(db)) == len(db):
                assert np.array_equal(got, want[0])
        else:
            remap = ambi.table.compact()
            assert ambi.table.n_perm == len(pts)  # vacuum is exact
            assert np.all(remap[remap >= 0] < ambi.table.n_nodes)
        ambi.table.check_invariants(len(pts))
    ambi.table.compact()
    assert ambi.table.n_perm == len(pts)
    # post-compact queries still exact
    got, _ = ambi.window(np.zeros(2), np.ones(2))
    assert len(got) == len(pts)


def test_churn_fixed_seeds():
    _churn_once(0, [0, 1, 2, 0, 0, 1, 2, 1, 0, 2])
    _churn_once(1, [2, 0, 2, 1, 1, 2, 0, 2])


if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(
        seed=st.integers(0, 3),
        ops=st.lists(st.integers(0, 2), min_size=3, max_size=10),
    )
    def test_churn_hypothesis(seed, ops):
        _churn_once(seed, ops)


def test_compact_preserves_serving_scaffolding():
    """Compaction mid-serving: the device table's row maps are rebased and
    subsequent deltas stay consistent."""
    pts = _f32_points(60_000, 2, 12)
    ambi = AMBI(pts, 120)
    srv = DeviceQueryServer.from_ambi(ambi, microbatch=4, compact_slack=0.05)
    rng = np.random.default_rng(13)
    for _ in range(6):
        c = rng.random((4, 2)) * 0.7 + 0.15
        c = c.astype(np.float32).astype(np.float64)
        srv.window(c - 0.03, c + 0.03)
    assert srv.stats.compactions >= 1
    assert ambi.table.n_perm <= 1.05 * len(pts)
    # scaffolding still aligned: leaf slots point at real leaf rows
    t = ambi.table
    assert np.all(t.is_leaf_row(srv.dev.leaf_rows))
    got = srv.window(np.zeros((1, 2)), np.ones((1, 2)))
    assert len(got[0]) == len(pts)


# --------------------------------------------------------------------------
# satellite regressions
# --------------------------------------------------------------------------
def test_device_table_pytree_roundtrip_recovers_n_points():
    """tree_unflatten used to leave n_points=None, crashing
    knn_query_batch_jax's ``min(k, dev.n_points)`` with a TypeError."""
    pts = _f32_points(20_000, 2, 14)
    idx = bulk_load(pts, 250, PageStore(250))
    dev = DeviceTable.from_index(idx)
    leaves, treedef = jax.tree_util.tree_flatten(dev)
    dev2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert dev2.n_points is None
    qs = _f32_points(4, 2, 15)
    got = knn_query_batch_jax(dev2, qs, 2 * len(pts))  # k > n: min() matters
    want = knn_query_batch_jax(dev, qs, 2 * len(pts))
    for a, b in zip(got, want):
        assert np.array_equal(a, b)
    assert dev2.live_points() == dev.n_points == len(pts)


def test_retrieval_server_lru_matches_reference_policy():
    """The OrderedDict LRU must replay the old dict+min-scan policy's
    hit/miss stats (and final hot set) bit for bit on a pinned stream."""
    import jax.numpy as jnp

    from repro.core import jax_index
    from repro.core.datasets import osm_like

    pts = osm_like(20_000, seed=3)
    cap = 8
    srv = RetrievalServer(pts, levels=6, adaptive=True, hot_capacity=cap)
    hot: dict[int, int] = {}
    tick = hits = misses = 0
    rng = np.random.default_rng(4)
    for step in range(25):
        width = 0.05 if step % 3 else 1.0  # focused with uniform bursts
        qs = (rng.random((16, 2)) * width + (0.6 if width < 1 else 0.0))
        qs = np.clip(qs, 0, 1).astype(np.float32)
        srv.knn(qs, 4)
        leaves = np.asarray(jax_index.route(srv.index, jnp.asarray(qs)))
        for leaf in leaves:  # the seed policy, verbatim
            tick += 1
            if int(leaf) in hot:
                hits += 1
            else:
                misses += 1
            hot[int(leaf)] = tick
            if len(hot) > cap:
                del hot[min(hot, key=hot.get)]
    assert srv.stats.hot_hits == hits
    assert srv.stats.cold_misses == misses
    assert dict(srv.hot) == hot


def test_ambi_refiner_takes_query_context_explicitly():
    """Refinement triggered outside a query (the serving loop) must flush
    against *that* query's geometry: refiners bound to different corners
    leave different unrefined patterns, and no stale instance state
    remains."""
    pts = _f32_points(60_000, 2, 16)
    a1 = AMBI(pts, 120)
    a2 = AMBI(pts, 120)
    assert not hasattr(a1, "_query_dist")
    lo1, hi1 = np.full(2, 0.02), np.full(2, 0.08)    # corner near origin
    lo2, hi2 = np.full(2, 0.92), np.full(2, 0.98)    # opposite corner
    assert a1.window_refiner(lo1, hi1)(0)
    assert a2.window_refiner(lo2, hi2)(0)
    for a in (a1, a2):
        a.table.check_invariants(len(pts))
        assert bool(a.table.unrefined.any())  # dense root stayed partial

    def unref_boxes(a):
        u = np.flatnonzero(a.table.unrefined)
        return {tuple(np.round(np.concatenate(
            [a.table.mbb_lo[r], a.table.mbb_hi[r]]), 6)) for r in u}

    assert unref_boxes(a1) != unref_boxes(a2)
    # the context that drove refinement keeps its own neighborhood hot:
    # the refined (active) subspaces sit near the bound query corner
    got, _ = a1.window(lo1, hi1)  # answers come straight off refined rows
    assert np.array_equal(np.sort(got), window_oracle(pts, lo1, hi1))
