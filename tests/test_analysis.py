"""The analyzer's own fixture suite.

Two halves: every committed bad fixture must produce exactly the
finding class it models (and the clean twin none), and the real source
tree must analyze clean — the analyzer gating CI must never be red on
the code it ships with.
"""

import os
import subprocess
import sys

import pytest

from repro.analysis import analyze_paths

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
FIX = os.path.join(HERE, "analysis_fixtures")
EMPTY_TESTS = os.path.join(FIX, "empty_tests")


def _findings(name):
    return analyze_paths([os.path.join(FIX, name)], tests_dir=EMPTY_TESTS)


# fixture file -> (expected checker, expected flagged lines)
BAD_FIXTURES = {
    "bad_unlocked_mutation.py": ("lock-discipline", [11]),
    "bad_unlocked_read.py": ("lock-discipline", [11]),
    "bad_checkpoint_unlocked.py": ("lock-discipline", [14, 15]),
    "bad_frontend_stats.py": ("lock-discipline", [11]),
    "bad_journal_outside_lock.py": ("journal-ordering", [10]),
    "bad_journal_after_mutation.py": ("journal-ordering", [12]),
    "bad_jit_host_sync.py": ("jit-purity", [14]),
    os.path.join("kernels", "bad_kernel_branch.py"): ("jit-purity", [14]),
    os.path.join("kernels", "ops.py"): ("jit-purity", [1]),
    "bad_fault_point.py": ("fault-coverage", [8]),
    "bad_missing_reason.py": ("annotation", [10]),
}


@pytest.mark.parametrize("name", sorted(BAD_FIXTURES))
def test_bad_fixture_flags(name):
    checker, lines = BAD_FIXTURES[name]
    found = _findings(name)
    assert found, f"{name}: expected {checker} findings, got none"
    assert [f.checker for f in found] == [checker] * len(lines)
    assert [f.line for f in found] == lines


def test_good_fixture_is_silent():
    assert _findings("good_guarded.py") == []


def test_bad_frontend_guarded_twin_not_flagged():
    # the same stat bump under self._mu (line 16) must not flag
    found = _findings("bad_frontend_stats.py")
    assert all(f.line < 14 for f in found)


def test_real_tree_is_clean():
    found = analyze_paths([os.path.join(REPO, "src")],
                          tests_dir=os.path.join(REPO, "tests"))
    assert found == [], "\n".join(f.render() for f in found)


def test_cli_exit_codes():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src"],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         os.path.join(FIX, "bad_unlocked_mutation.py"),
         "--tests-dir", EMPTY_TESTS],
        cwd=REPO, env=env, capture_output=True, text=True)
    assert bad.returncode == 1
    assert "lock-discipline" in bad.stdout


def test_regression_checkpoint_shape_is_caught():
    """The pre-fix form of DeviceQueryServer.checkpoint() (snapshot
    without quiescing writers) is exactly bad_checkpoint_unlocked.py;
    the fixed form takes the writer lock and analyzes clean — covered
    by test_real_tree_is_clean."""
    found = _findings("bad_checkpoint_unlocked.py")
    msgs = " ".join(f.message for f in found)
    assert "compact" in msgs and "truncate" in msgs


def test_regression_frontend_stats_shape_is_caught():
    """Pre-fix frontend drop path bumped stats outside self._mu; the
    fixture models it and the analyzer flags only the unguarded bump."""
    found = _findings("bad_frontend_stats.py")
    assert len(found) == 1
    assert "rejected" in found[0].message
