"""Parallel bulk loading: golden IOStats (Figure-11 quantities), merged-table
invariants, and the merge+graft row-permutation audit.

The makespan/total page-I/O numbers of ``parallel_bulk_load`` are the
paper's Figure-11 measurements; they were previously untested, so any
accounting drift in the central sample/stream or per-server builds went
unnoticed.  The goldens below pin them on a seeded 100k OSM-like dataset.

The audit tests exercise the interleaving the distributed path actually
produces — per-server AMBI tables partially refined (grafted) locally,
merged into one global table, then grafted further on demand — and assert
after every step that ``perm``'s live segments stay disjoint and together
a permutation of the dataset rows.
"""
import numpy as np
import pytest

from engines import f32_points
from repro.core import AMBI, Index, NodeTable, PageStore, refine_subspace
from repro.core.datasets import osm_like
from repro.core.distributed import parallel_bulk_load
from repro.core.nodetable import ragged_ranges
from repro.core.pagestore import branch_capacity, leaf_capacity
from repro.core.queries import knn_query, window_oracle, window_query
from test_nodetable import _sibling_leaf_overlap

try:  # optional dev dependency (see requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# golden IOStats: the Figure-11 quantities on the seeded 100k dataset
# --------------------------------------------------------------------------
GOLDEN_100K = {
    # m: (makespan_io, total_io, central_io)
    1: (721, 721, 0),
    2: (295, 886, 296),
    4: (149, 892, 296),
    8: (75, 900, 300),
}


@pytest.fixture(scope="module")
def pts_100k():
    return osm_like(100_000, seed=17)


@pytest.mark.parametrize("m", sorted(GOLDEN_100K))
def test_parallel_bulk_load_golden_io(pts_100k, m):
    build = parallel_bulk_load(pts_100k, m=m, buffer_pages=400)
    makespan, total, central = GOLDEN_100K[m]
    assert build.makespan_io == makespan
    assert build.total_io == total
    assert build.central_io.total == central
    assert len(build.indexes) == m
    assert sum(len(rm) for rm in build.row_maps) == len(pts_100k)


def test_parallel_speedup_shape(pts_100k):
    """Makespan falls roughly linearly with m while total I/O stays within
    a constant factor of the single-server cost (the paper's claim)."""
    makespans = {
        m: parallel_bulk_load(pts_100k, m=m, buffer_pages=400).makespan_io
        for m in (1, 4)
    }
    assert makespans[4] < makespans[1] / 2
    total4 = GOLDEN_100K[4][1]
    assert total4 < 2 * GOLDEN_100K[1][1]


# --------------------------------------------------------------------------
# merged_table invariants (the test_nodetable property checks, distributed)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m", [1, 3, 4])
def test_merged_table_invariants(pts_100k, m):
    pts = pts_100k[:40_000]
    build = parallel_bulk_load(pts, m=m, buffer_pages=600)
    merged = build.merged_table()
    merged.check_invariants(len(pts))
    assert merged.child_count[0] == m
    # zero overlap within each server's sibling-leaf blocks (continuous data)
    assert _sibling_leaf_overlap(merged) < 1e-9
    # perm is a permutation of the global dataset rows
    live = np.flatnonzero(merged.leaf_start >= 0)
    sel = ragged_ranges(merged.leaf_start[live], merged.leaf_count[live])
    np.testing.assert_array_equal(np.sort(merged.perm[sel]), np.arange(len(pts)))
    # and the merged index answers globally
    gidx = build.merged_index(pts, buffer_pages=300)
    rng = np.random.default_rng(5)
    for _ in range(4):
        c = rng.random(2)
        res, _ = window_query(gidx, c - 0.03, c + 0.03)
        assert np.array_equal(np.sort(res), window_oracle(pts, c - 0.03, c + 0.03))


# --------------------------------------------------------------------------
# merge + graft interleavings: the row-permutation audit
# --------------------------------------------------------------------------
def _audit_perm(table: NodeTable, n_points: int) -> None:
    """Live perm segments must be in-bounds, pairwise disjoint, and
    together a permutation of the dataset rows."""
    live = np.flatnonzero(table.leaf_start >= 0)
    starts = table.leaf_start[live]
    counts = table.leaf_count[live]
    assert np.all(starts + counts <= table.n_perm)
    sel = ragged_ranges(starts, counts)
    assert len(np.unique(sel)) == len(sel), "live perm segments overlap"
    vals = table.perm[sel]
    np.testing.assert_array_equal(np.sort(vals), np.arange(n_points))


def _merged_partial_ambi(pts, m, M, seed, refine_windows=1):
    """Per-server AMBI tables, partially refined locally, then merged."""
    d = pts.shape[1]
    rng = np.random.default_rng(seed)
    chunks = np.array_split(rng.permutation(len(pts)), m)
    tables, row_maps, offsets = [], [], []
    off = 0
    for rows in chunks:
        a = AMBI(pts[rows], M)
        for _ in range(refine_windows):  # local grafts before the merge
            c = rng.random(d) * 0.6
            a.window(c, c + 0.25)
        tables.append(a.table)
        row_maps.append(rows)
        offsets.append(off)
        off += a.store.allocated_pages
    return NodeTable.merged(tables, row_maps, offsets, root_page=off), off


def _graft_all(merged, pts, store, rng, audit_every=1):
    """Refine every remaining unrefined row of the merged table in a
    random order, auditing the permutation as grafts interleave."""
    d = pts.shape[1]
    c_l, c_b = leaf_capacity(d), branch_capacity(d)
    step = 0
    while bool(merged.unrefined.any()):
        rows = np.flatnonzero(merged.unrefined)
        row = int(rng.choice(rows))
        idx = merged.point_rows(row).copy()
        merged.graft(row, refine_subspace(pts, idx, c_l, c_b, store))
        step += 1
        if step % audit_every == 0:
            _audit_perm(merged, len(pts))
    return step


def test_merge_then_graft_keeps_permutation():
    # M small relative to the per-server page count so the adaptive build
    # leaves genuinely unrefined subspaces behind for post-merge grafting
    pts = f32_points(60_000, 2, 41, "skew")
    merged, pages = _merged_partial_ambi(pts, m=3, M=25, seed=1)
    assert bool(merged.unrefined.any())  # the merge carried unrefined rows
    _audit_perm(merged, len(pts))
    merged.check_invariants(len(pts))
    store = PageStore(300)
    store.mark_allocated(int(merged.page_id.max()) + 1)
    rng = np.random.default_rng(2)
    grafts = _graft_all(merged, pts, store, rng)
    assert grafts >= 1  # the interleaving actually exercised graft
    merged.check_invariants(len(pts))
    # fully refined merged table answers exactly
    d = pts.shape[1]
    idx = Index(merged, d, leaf_capacity(d), branch_capacity(d), store, pts)
    qrng = np.random.default_rng(3)
    for _ in range(4):
        c = qrng.random(2)
        res, _ = window_query(idx, c - 0.04, c + 0.04)
        assert np.array_equal(np.sort(res), window_oracle(pts, c - 0.04, c + 0.04))
        q = qrng.random(2)
        got, _ = knn_query(idx, q, 8)
        d2 = np.sum((pts - q) ** 2, axis=1)
        np.testing.assert_array_equal(np.sort(d2[got]), np.sort(d2)[:8])


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(
        m=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
        refine_windows=st.integers(0, 3),
    )
    def test_merge_graft_interleavings_property(m, seed, refine_windows):
        """perm stays a permutation and live leaf ranges stay disjoint
        under randomized merge+graft interleavings (a small buffer keeps
        the per-server builds adaptive, so unrefined rows cross the
        merge)."""
        pts = f32_points(24_000, 2, seed % 7, "skew")
        merged, _ = _merged_partial_ambi(
            pts, m=m, M=12, seed=seed, refine_windows=refine_windows
        )
        _audit_perm(merged, len(pts))
        store = PageStore(250)
        store.mark_allocated(int(merged.page_id.max()) + 1)
        _graft_all(merged, pts, store, np.random.default_rng(seed))
        _audit_perm(merged, len(pts))
        merged.check_invariants(len(pts))
