"""Fault-injection plane + resilience primitives (PR-6).

Unit-level: the seeded :class:`FaultPlan` schedule is a pure function of
``(seed, rule, matching-call index)`` — replayable, filterable, boundable
— and the retry/deadline/breaker primitives behave per spec under
virtual clocks.  Integration-level: ``DeviceQueryServer`` absorbs
bounded faults transparently (NumPy-engine parity), fails fast through
open breakers, degrades with honest certificates, and repairs.
"""
import itertools

import numpy as np
import pytest

from repro.core import PageStore
from repro.core.distributed_jax import ShardUnavailable
from repro.serve.faults import (
    FAILURE_POINTS,
    FaultError,
    FaultPlan,
    FaultRule,
)
from repro.serve.resilience import (
    CircuitBreaker,
    Deadline,
    DeadlineExceeded,
    RetryExhausted,
    RetryPolicy,
)

from engines import NumpyEngine, ServerEngine, build_fmbi, f32_points


# --------------------------------------------------------------------------
# FaultPlan schedules
# --------------------------------------------------------------------------
def _fire_seq(plan, point, n, **ctx):
    """Call ``plan.fire`` n times; return the 1-based indices that raised."""
    fired = []
    for i in range(1, n + 1):
        try:
            plan.fire(point, **ctx)
        except FaultError:
            fired.append(i)
    return fired


def test_rule_rejects_unknown_point():
    with pytest.raises(ValueError, match="unknown failure point"):
        FaultRule("not_a_point")
    assert "shard_dispatch" in FAILURE_POINTS


def test_at_calls_schedule_is_exact():
    plan = FaultPlan([FaultRule("host_refine", at_calls={2, 4})])
    assert _fire_seq(plan, "host_refine", 6) == [2, 4]
    assert plan.total_fires == 2
    assert plan.fires_at("host_refine") == 2
    assert [c for _, c, _ in plan.log] == [2, 4]


def test_rate_schedule_is_seed_deterministic():
    mk = lambda seed: FaultPlan(
        [FaultRule("shard_dispatch", rate=0.5)], seed=seed
    )
    a, b = mk(7), mk(7)
    sa = _fire_seq(a, "shard_dispatch", 40)
    sb = _fire_seq(b, "shard_dispatch", 40)
    assert sa == sb and 0 < len(sa) < 40  # same seed -> bit-identical plan
    assert _fire_seq(mk(8), "shard_dispatch", 40) != sa


def test_rules_draw_independent_streams():
    # two identical-rate rules at different points must not mirror each
    # other: each draws from default_rng([seed, rule_index])
    plan = FaultPlan(
        [
            FaultRule("shard_dispatch", rate=0.5),
            FaultRule("apply_delta", rate=0.5),
        ],
        seed=3,
    )
    a = _fire_seq(plan, "shard_dispatch", 40)
    b = _fire_seq(plan, "apply_delta", 40)
    assert a != b


def test_match_filter_gates_counters():
    plan = FaultPlan(
        [FaultRule("shard_dispatch", at_calls={1}, match={"shard": 1})]
    )
    plan.fire("shard_dispatch", shard=0)  # no match: no fire, no advance
    plan.fire("shard_dispatch", shard=2)
    with pytest.raises(FaultError) as ei:
        plan.fire("shard_dispatch", shard=1)  # first MATCHING call fires
    assert ei.value.ctx == {"shard": 1}
    assert plan.total_fires == 1


def test_max_fires_bounds_a_storm():
    plan = FaultPlan([FaultRule("host_refine", rate=1.0, max_fires=2)])
    assert _fire_seq(plan, "host_refine", 6) == [1, 2]
    assert plan.total_fires == 2


def test_disarm_is_inert_rearm_resumes():
    plan = FaultPlan.single("snapshot_save", at_call=1)
    plan.disarm()
    assert _fire_seq(plan, "snapshot_save", 3) == []  # no fire, no advance
    plan.rearm()
    with pytest.raises(FaultError):  # still call #1 of the schedule
        plan.fire("snapshot_save")


def test_storm_constructor_reproducible():
    points = ("shard_dispatch", "apply_delta", "host_refine")
    logs = []
    for _ in range(2):
        plan = FaultPlan.storm(points, 0.4, seed=11, max_fires_per_point=3)
        for i in range(30):
            try:
                plan.fire(points[i % 3], step=i)
            except FaultError:
                pass
        logs.append(plan.log)
    assert logs[0] == logs[1] and len(logs[0]) > 0
    per_point = {p: plan.fires_at(p) for p in points}
    assert all(v <= 3 for v in per_point.values())


def test_pagestore_hook_fires_reads_only():
    store = PageStore(4)
    plan = FaultPlan.single("pagestore_read", at_call=1)
    store.fault_hook = plan.pagestore_hook()
    pid = store.alloc()
    store.write(pid)  # writes never fire
    assert plan.total_fires == 0
    with pytest.raises(FaultError):
        store.read(pid)
    store.read(pid)  # schedule spent: reads flow again
    assert plan.total_fires == 1


# --------------------------------------------------------------------------
# resilience primitives under virtual clocks
# --------------------------------------------------------------------------
class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_retry_absorbs_transient_failures():
    calls = itertools.count()
    retried = []

    def flaky():
        if next(calls) < 2:
            raise RuntimeError("transient")
        return "ok"

    pol = RetryPolicy(max_attempts=3, sleep=lambda s: None)
    assert pol.call(flaky, on_retry=lambda a, e: retried.append(a)) == "ok"
    assert retried == [1, 2]


def test_retry_exhausted_carries_last_cause():
    pol = RetryPolicy(max_attempts=2, sleep=lambda s: None)
    boom = ValueError("always")
    with pytest.raises(RetryExhausted) as ei:
        pol.call(lambda: (_ for _ in ()).throw(boom))
    assert ei.value.attempts == 2
    assert ei.value.__cause__ is boom


def test_retry_no_retry_types_propagate_immediately():
    calls = itertools.count()

    def fail():
        next(calls)
        raise DeadlineExceeded("budget spent")

    pol = RetryPolicy(max_attempts=5, sleep=lambda s: None)
    with pytest.raises(DeadlineExceeded):
        pol.call(fail)
    assert next(calls) == 1  # exactly one attempt was made


def test_backoff_delays_exponential_and_seeded():
    slept = []
    pol = RetryPolicy(
        max_attempts=4, base_delay_s=0.1, backoff=2.0, max_delay_s=10.0,
        jitter=0.0, sleep=slept.append,
    )
    with pytest.raises(RetryExhausted):
        pol.call(lambda: (_ for _ in ()).throw(RuntimeError()))
    np.testing.assert_allclose(slept, [0.1, 0.2, 0.4])
    # jittered delays are a pure function of the policy seed
    mk = lambda: RetryPolicy(
        max_attempts=1, base_delay_s=0.1, jitter=0.5, seed=9
    )
    assert [mk().delay(i) for i in (1, 2)] == [mk().delay(i) for i in (1, 2)]


def test_retry_jitter_deterministic_across_threads():
    """The jitter draw is a pure function of (seed, call-id, attempt): two
    threads hammering ONE shared policy concurrently must each see exactly
    the delays a single-threaded run of their call site sees — a shared
    rng stream would interleave nondeterministically."""
    import threading

    tl = threading.local()
    pol = RetryPolicy(max_attempts=4, base_delay_s=0.1, jitter=0.5, seed=7,
                      sleep=lambda s: tl.slept.append(s))

    def boom():
        raise RuntimeError("down")

    def delays_for(key):
        tl.slept = []
        with pytest.raises(RetryExhausted):
            pol.call(boom, call_key=key)
        return list(tl.slept)

    # single-threaded reference, then 2 threads x 50 interleaved calls
    expect = {key: delays_for(key) for key in ("lane-a", "lane-b")}
    results = {"lane-a": [], "lane-b": []}

    def worker(key):
        for _ in range(50):
            results[key].append(delays_for(key))

    threads = [threading.Thread(target=worker, args=(k,))
               for k in ("lane-a", "lane-b")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for key in ("lane-a", "lane-b"):
        assert len(results[key]) == 50
        assert all(s == expect[key] for s in results[key])
    # distinct call sites decorrelate; same site reproduces exactly
    assert len(expect["lane-a"]) == 3
    assert expect["lane-a"] != expect["lane-b"]


def test_deadline_caps_backoff_and_raises():
    clk = VirtualClock()
    dl = Deadline(1.0, clock=clk)
    assert dl.remaining() == 1.0 and not dl.expired
    slept = []

    def sleep(s):
        slept.append(s)
        clk.t += s

    pol = RetryPolicy(
        max_attempts=10, base_delay_s=0.8, backoff=1.0, jitter=0.0,
        sleep=sleep,
    )
    with pytest.raises(DeadlineExceeded):
        pol.call(
            lambda: (_ for _ in ()).throw(RuntimeError()), deadline=dl
        )
    # first pause is the full 0.8s backoff; the next is clipped to the
    # 0.2s remaining; then the budget is spent before another attempt
    np.testing.assert_allclose(slept, [0.8, 0.2])
    assert Deadline(None, clock=clk).remaining() == float("inf")


def test_breaker_state_machine():
    clk = VirtualClock()
    br = CircuitBreaker(failure_threshold=2, cooldown_s=30.0, clock=clk)
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed" and br.allow()  # 1 < threshold
    br.record_failure()
    assert br.state == "open" and br.open_count == 1
    assert not br.allow()  # fail fast during cooldown
    clk.t += 30.0
    assert br.allow() and br.state == "half_open"
    assert not br.allow()  # single trial in flight
    br.record_failure()  # trial failed: re-open for another cooldown
    assert br.state == "open" and br.open_count == 2
    clk.t += 30.0
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.failures == 0
    # success resets the consecutive-failure count
    br.record_failure()
    br.record_success()
    br.record_failure()
    assert br.state == "closed"


# --------------------------------------------------------------------------
# DeviceQueryServer integration
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def static_setup():
    pts = f32_points(700, 2, seed=5)
    index = build_fmbi(pts, M=64)
    rng = np.random.default_rng(2)
    c = rng.random((12, 2))
    los = np.clip(c - 0.12, 0, 1)
    his = np.clip(c + 0.12, 0, 1)
    qs = rng.random((12, 2))
    return pts, index, los, his, qs


def test_server_absorbs_bounded_faults(static_setup):
    pts, index, los, his, qs = static_setup
    oracle = NumpyEngine(index)
    plan = FaultPlan(
        [FaultRule("shard_dispatch", at_calls={1, 3})], seed=0
    )
    eng = ServerEngine(index, shards=2, fault_plan=plan, microbatch=8)
    ref_w = oracle.window(los, his)
    got_w = eng.window(los, his)
    for a, b in zip(got_w, ref_w):
        assert np.array_equal(np.sort(a), np.sort(b))
    got_k = eng.knn(qs, 5)
    for a, b in zip(got_k, oracle.knn(qs, 5)):
        assert np.array_equal(a, b)
    assert plan.total_fires == 2  # both scheduled faults actually hit
    assert eng.srv.stats.retries >= 2  # ...and were retried away


def test_validation_precise_errors(static_setup):
    pts, index, los, his, qs = static_setup
    srv = ServerEngine(index, microbatch=8).srv
    bad = los.copy()
    bad[3, 1] = np.nan
    with pytest.raises(ValueError, match="query 3 contains NaN"):
        srv.window(bad, his)
    with pytest.raises(ValueError, match=r"expected shape \(Q, 2\)"):
        srv.knn(np.zeros((4, 3)), 2)
    with pytest.raises(ValueError, match="numeric array"):
        srv.window(np.array([["a", "b"]], dtype=object), his[:1])
    with pytest.raises(ValueError, match="complex"):
        srv.knn(np.zeros((1, 2), dtype=np.complex128), 2)
    with pytest.raises(ValueError, match="los/his shape mismatch"):
        srv.window(los[:3], his[:4])
    with pytest.raises(ValueError, match="k must be a positive integer"):
        srv.knn(qs, 0)
    with pytest.raises(ValueError, match="k must be a positive integer"):
        srv.knn(qs, 2.5)


def test_deadline_exceeded_surfaces(static_setup):
    pts, index, los, his, qs = static_setup
    clk = VirtualClock()
    srv = ServerEngine(
        index, shards=2, deadline_s=5.0, clock=clk, microbatch=8
    ).srv
    clk.t = 0.0
    assert len(srv.window(los[:2], his[:2])) == 2  # within budget
    orig_deadline = srv._deadline

    def slow_deadline():
        dl = orig_deadline()
        clk.t += 10.0  # the batch budget is spent before dispatch
        return dl

    srv._deadline = slow_deadline
    with pytest.raises(DeadlineExceeded):
        srv.window(los[:2], his[:2])


def test_breaker_opens_degrades_and_repairs(static_setup):
    pts, index, los, his, qs = static_setup
    oracle = NumpyEngine(index)
    clk = VirtualClock()
    plan = FaultPlan(
        [FaultRule("shard_dispatch", rate=1.0, match={"shard": 1})], seed=0
    )
    srv = ServerEngine(
        index, shards=2, fault_plan=plan, microbatch=32,
        retry=RetryPolicy(max_attempts=2, sleep=lambda s: None),
        breaker_threshold=1, breaker_cooldown_s=1e9, clock=clk,
    ).srv
    full_lo = np.zeros((1, 2))
    full_hi = np.ones((1, 2))
    # without certs the outage is an error, not a silent partial answer
    with pytest.raises(ShardUnavailable):
        srv.window(full_lo, full_hi)
    res, certs = srv.window(full_lo, full_hi, return_certs=True)
    assert not certs[0].complete and certs[0].missing_shards == (1,)
    assert srv.breakers[1].state == "open"
    assert srv.stats.degraded_queries >= 1
    fires_before = plan.total_fires
    srv.window(full_lo, full_hi, return_certs=True)  # breaker: fail fast
    assert plan.total_fires == fires_before  # no dispatch, no new faults
    # repair rebuilds the shard from the host table and closes the breaker
    plan.disarm()
    assert srv.repair() == [1]
    assert srv.breakers[1].state == "closed"
    res, certs = srv.window(full_lo, full_hi, return_certs=True)
    assert certs[0].complete
    assert np.array_equal(
        np.sort(res[0]), np.sort(oracle.window(full_lo, full_hi)[0])
    )
