"""Roofline machinery: HLO parsing (incl. while-loop multipliers) and the
analytic FLOPs model cross-checked against XLA cost_analysis."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import roofline
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.launch.mesh import make_mesh, use_mesh
from repro.models.sharding import MeshAxes


def test_shape_bytes():
    assert roofline._shape_bytes("bf16[2,3,4]{2,1,0}") == 48
    assert roofline._shape_bytes("f32[10]") == 40
    assert roofline._shape_bytes("(f32[4], bf16[8])") == 32
    assert roofline._shape_bytes("pred[]") == 1  # scalar: one byte


def test_while_loop_multiplier_recovered():
    """Collectives inside a scanned body must be multiplied by trip count."""
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro import roofline
from repro.launch.mesh import make_mesh, use_mesh
mesh = make_mesh((4,), ("m",))
L, D = 7, 64
def f(ws, x):
    def body(c, w):
        y = c @ w                      # sharded matmul -> all-reduce/gather
        return y, None
    out, _ = jax.lax.scan(body, x, ws)
    return out.sum()
ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32,
                          sharding=NamedSharding(mesh, P(None, "m", None)))
x = jax.ShapeDtypeStruct((8, D), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "m")))
with use_mesh(mesh):
    c = jax.jit(f).lower(ws, x).compile()
res = roofline.parse_collectives(c.as_text())
counts = sum(res["counts"].values())
assert counts > 0, "no collectives found"
per = res["total_bytes"] / max(counts, 1)
# bytes must reflect the x7 trip count: far larger than one op's payload
assert res["total_bytes"] >= 7 * 8 * 16 * 4, res
print("MULT-OK", res["total_bytes"])
"""
    res = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]),
        timeout=300,
    )
    assert "MULT-OK" in res.stdout, res.stdout + res.stderr


def _tiny_cfg(**kw):
    base = dict(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=128, dtype="float32", chunk_q=32,
    )
    base.update(kw)
    return ModelConfig(**base)


def test_analytic_flops_close_to_xla_forward():
    """Forward-only FLOPs: analytic model within 25% of XLA's count on a
    small dense config (unrolled enough that nothing hides in while loops:
    single q-chunk, single loss chunk)."""
    cfg = _tiny_cfg()
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="prefill")
    mesh = make_mesh((1, 1), ("data", "model"))
    axes = MeshAxes()
    params = M.abstract_params(cfg, mesh, jnp.float32)
    inputs = M.input_specs(cfg, shape, mesh)
    with use_mesh(mesh):
        c = jax.jit(lambda p, b: M.prefill(p, cfg, b, axes)).lower(
            params, inputs
        ).compile()
    xla = roofline.cost_analysis_dict(c)["flops"]
    # scan over 2 layers counted once by XLA -> add one body back
    body = xla  # lower 1-layer variant for the body estimate
    cfg1 = dataclasses.replace(cfg, n_layers=1)
    params1 = M.abstract_params(cfg1, mesh, jnp.float32)
    with use_mesh(mesh):
        c1 = jax.jit(lambda p, b: M.prefill(p, cfg1, b, axes)).lower(
            params1, inputs
        ).compile()
    xla1 = roofline.cost_analysis_dict(c1)["flops"]
    per_layer = xla - xla1 if xla > xla1 else 0.0
    xla_full = xla1 + per_layer * cfg.n_layers  # body-once corrected
    ana = roofline.analytic_flops(cfg, shape)["fwd_flops"]
    # prefill computes logits on the last position only; analytic model
    # includes the same head term
    assert 0.5 < ana / max(xla_full, 1) < 2.0, (ana, xla_full)


def test_roofline_terms_and_dominance():
    t = roofline.roofline_terms(
        flops=1e15, hbm_bytes=1e12, coll_bytes=1e11, chips=256
    )
    assert t["dominant"] == "compute_s"
    assert 0 < t["roofline_fraction"] <= 1.0
    t2 = roofline.roofline_terms(
        flops=1e12, hbm_bytes=1e14, coll_bytes=1e11, chips=256
    )
    assert t2["dominant"] == "memory_s"


def test_probe_extrapolation_linear():
    probe = {
        "blocks1": {"flops": 130.0, "bytes_accessed": 1300.0,
                    "collective_bytes": 13.0},
        "blocks2": {"flops": 230.0, "bytes_accessed": 2300.0,
                    "collective_bytes": 23.0},
    }
    out = roofline.probe_extrapolate(probe, n_blocks=10)
    assert out["flops"] == pytest.approx(30.0 + 100.0 * 10)
    assert out["collective_bytes"] == pytest.approx(3.0 + 10.0 * 10)
