"""Training infrastructure: optimizers, checkpointing, data pipeline,
linear-attention engine, end-to-end loss decrease + restart."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import PipelineState, TokenPipeline
from repro.models.linear_attn import (
    bounded_log_decay,
    chunked_gla,
    gla_reference,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adafactor, adamw, pick_for


# -- optimizers -------------------------------------------------------------
@pytest.mark.parametrize("make", [adamw, adafactor])
def test_optimizer_minimizes_quadratic(make):
    opt = make(lr=0.1)
    params = {"a": {"w": jnp.ones((4, 8)) * 3.0}, "b": [jnp.ones(5)]}
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(x**2) for x in jax.tree.leaves(p))

    l0 = loss(params)
    for step in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params,
                                   jnp.asarray(step, jnp.int32))
    assert float(loss(params)) < float(l0) * 0.05


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.ones((16, 32)), "b": jnp.ones(16)}
    st_ = opt.init(params)
    # b first in canonical (sorted-key) flatten order
    sizes = [sum(x.size for x in jax.tree.leaves(s)) for s in st_]
    assert sizes[1] == 16 + 32  # factored: row+col, not 16*32
    assert sizes[0] == 16


def test_pick_for_sizes():
    from repro.configs.base import get_config

    assert pick_for(get_config("arctic-480b")) == "adafactor"
    assert pick_for(get_config("qwen3-0.6b")) == "adamw"


# -- chunked GLA engine -------------------------------------------------------
@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([4, 8, 16]),
    st.booleans(),
    st.booleans(),
)
@settings(max_examples=10, deadline=None)
def test_chunked_gla_equals_recurrence(seed, chunk, scalar_decay, bonus):
    rng = np.random.default_rng(seed)
    B, S, H, dk, dv = 2, 32, 2, 8, 8
    r = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, dv)), jnp.float32)
    wshape = (B, S, H, 1) if scalar_decay else (B, S, H, dk)
    lw = bounded_log_decay(jnp.asarray(rng.normal(0, 1, wshape), jnp.float32))
    u = (jnp.asarray(rng.normal(0, 1, (H, dk)), jnp.float32)
         if bonus else None)
    s0 = jnp.asarray(rng.normal(0, 1, (B, H, dk, dv)), jnp.float32)
    y1, f1 = chunked_gla(r, k, v, lw, chunk=chunk, u=u, state0=s0)
    y2, f2 = gla_reference(r, k, v, lw, u=u, state0=s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=2e-3, atol=2e-4)


# -- checkpoint manager -------------------------------------------------------
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"p": {"w": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "opt": [{"m": jnp.ones(3)}, {"v": jnp.zeros(2)}]}
    for step in (1, 2, 3):
        mgr.save(step, tree, extra={"step": step, "note": "x"})
    assert mgr.steps() == [2, 3]  # keep=2 garbage-collected step 1
    got, extra = mgr.restore()
    assert extra["step"] == 3
    np.testing.assert_array_equal(np.asarray(got["p"]["w"]),
                                  np.asarray(tree["p"]["w"]))
    assert isinstance(got["opt"], list) and len(got["opt"]) == 2


def test_checkpoint_crash_safety(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, {"w": jnp.ones(3)}, extra={"step": 5})
    # simulate a crashed writer: snapshot without the commit marker
    bad = pathlib.Path(tmp_path) / "step_9"
    (bad / "arrays").mkdir(parents=True)
    (bad / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 5  # incomplete snapshot ignored


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(7, {"w": jnp.full(4, 7.0)}, extra={"step": 7})
    mgr.wait()
    got, extra = mgr.restore()
    assert extra["step"] == 7


# -- data pipeline -------------------------------------------------------------
def test_pipeline_deterministic_and_balanced():
    p1 = TokenPipeline(vocab=100, seq_len=32, n_docs=512, n_shards=4, seed=3)
    p2 = TokenPipeline(vocab=100, seq_len=32, n_docs=512, n_shards=4, seed=3)
    b1, b2 = p1.next_batch(4, shard=1), p2.next_batch(4, shard=1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert p1.shard_balance() < 1.25  # FMBI-balanced shards (paper: ~1.06)


def test_pipeline_state_restore():
    p = TokenPipeline(vocab=100, seq_len=16, n_docs=64, seed=0)
    p.next_batch(2)
    saved = p.state.as_dict()
    a = p.next_batch(2)["tokens"]
    p2 = TokenPipeline(vocab=100, seq_len=16, n_docs=64, seed=0)
    p2.state = PipelineState.from_dict(saved)
    b = p2.next_batch(2)["tokens"]
    np.testing.assert_array_equal(a, b)


# -- end-to-end train loop -----------------------------------------------------
def test_train_loop_loss_decreases_and_restarts(tmp_path):
    from repro.launch.train import main

    losses = main([
        "--arch", "qwen3-0.6b", "--steps", "8", "--batch", "4",
        "--seq", "64", "--reduced", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "4", "--lr", "1e-3",
    ])
    assert losses[-1] < losses[0]
    # restart: resumes from step 8 checkpoint, runs 2 more
    more = main([
        "--arch", "qwen3-0.6b", "--steps", "10", "--batch", "4",
        "--seq", "64", "--reduced", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "4", "--lr", "1e-3",
    ])
    assert len(more) == 2  # only steps 8..9 ran after restore
