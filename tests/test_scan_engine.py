"""Vectorized scan engine vs the scalar reference implementation.

Two layers of protection:

  * unit: the prefix-sum Step-2 replay (`_replay_step2`) must reproduce the
    scalar ``SubspaceBuffers`` state machine decision-for-decision on
    adversarial assignment streams;
  * golden: a full ``bulk_load`` under both engines must produce identical
    ``IOStats``, identical page layout, and identical leaf partitions on a
    fixed-seed dataset — and both must match the constants captured from the
    seed (pre-vectorization) implementation, so neither engine can drift.
"""
import numpy as np
import pytest

from repro.core import (
    PageStore,
    bulk_load,
    knn_oracle,
    knn_query,
    knn_query_batch,
    window_oracle,
    window_query,
    window_query_batch,
)
from repro.core.fmbi import SubspaceBuffers, _replay_step2
from repro.core.datasets import gaussian, osm_like

# captured from the seed scalar implementation (commit b71a949) on the
# fixed-seed datasets below: (reads, writes, allocated_pages)
GOLDEN_OSM_120K = (555, 614, 411)
GOLDEN_GAUSS_120K = (530, 589, 411)


def _scalar_state(assign, c_b, c_l, M, alpha, store):
    bufs = SubspaceBuffers(c_b, c_l, M, store, [alpha] * c_b)
    for start in range(0, len(assign), c_l):
        a = assign[start : start + c_l]
        for s in np.unique(a):
            bufs.add_points(int(s), int((a == s).sum()))
    return bufs


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("skew", [False, True])
def test_replay_matches_scalar_buffers(seed, skew):
    rng = np.random.default_rng(seed)
    c_b, c_l, M, alpha = 20, 8, 25, 1
    n = 4000
    if skew:
        raw = rng.zipf(1.5, n)  # heavy skew: some subspaces flush repeatedly
        assign = (raw % c_b).astype(np.int64)
    else:
        assign = rng.integers(0, c_b, n).astype(np.int64)
    st_s, st_v = PageStore(M), PageStore(M)
    bufs = _scalar_state(assign, c_b, c_l, M, alpha, st_s)
    counts, disk, active = _replay_step2(assign, c_b, c_l, M, alpha, st_v)
    assert st_v.stats.writes == st_s.stats.writes
    np.testing.assert_array_equal(counts, bufs.counts)
    np.testing.assert_array_equal(disk, bufs.disk_pages)
    np.testing.assert_array_equal(active, bufs.active)


def _leaf_partition(idx):
    return sorted(
        (int(l.page_id), tuple(sorted(l.point_idx.tolist())))
        for l in idx.root.iter_leaves()
    )


@pytest.mark.parametrize(
    "dataset,M,golden",
    [
        (lambda: osm_like(120_000, seed=3), 205, GOLDEN_OSM_120K),
        # tiny buffer: exercises the Step-5 dense recursion under both engines
        (lambda: gaussian(120_000, 2, seed=5), 230, GOLDEN_GAUSS_120K),
    ],
    ids=["osm120k", "gauss120k-dense"],
)
def test_bulk_load_engines_identical_and_golden(dataset, M, golden):
    pts = dataset()
    results = {}
    for mode in ("scalar", "vectorized"):
        store = PageStore(M)
        idx = bulk_load(pts, M, store, step2=mode)
        results[mode] = (
            store.stats.reads,
            store.stats.writes,
            store.allocated_pages,
            _leaf_partition(idx),
        )
    # identical IOStats + page layout + leaf partition between engines
    assert results["scalar"][:3] == results["vectorized"][:3]
    assert results["scalar"][3] == results["vectorized"][3]
    # ... and both match the seed-captured constants
    assert results["vectorized"][:3] == golden


# --------------------------------------------------------------------------
# batched query execution
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def built():
    pts = osm_like(80_000, seed=9)
    return pts, bulk_load(pts, 250)


def test_window_query_batch_matches_oracle(built):
    pts, idx = built
    rng = np.random.default_rng(4)
    c = rng.random((24, 2)) * 0.8
    w = rng.uniform(0.01, 0.06, (24, 1))
    los, his = c - w, c + w
    res, io = window_query_batch(idx, los, his)
    assert len(res) == 24 and io.total >= 0
    for i in range(24):
        ref = window_oracle(pts, los[i], his[i])
        assert sorted(res[i].tolist()) == sorted(ref.tolist())


def test_window_query_batch_amortizes_io(built):
    pts, _ = built
    rng = np.random.default_rng(5)
    c = rng.random((32, 2)) * 0.8
    los, his = c - 0.04, c + 0.04
    idx_b = bulk_load(pts, 250)
    _, io_batch = window_query_batch(idx_b, los, his)
    idx_s = bulk_load(pts, 250)  # identical build, fresh LRU state
    singles = 0
    for i in range(32):
        _, io = window_query(idx_s, los[i], his[i])
        singles += io.total
    assert io_batch.total <= singles


def test_knn_query_batch_matches_oracle(built):
    pts, idx = built
    rng = np.random.default_rng(6)
    qs = rng.random((12, 2))
    for k in (1, 8, 32):
        res, io = knn_query_batch(idx, qs, k)
        assert io.total >= 0
        for i, q in enumerate(qs):
            ref = knn_oracle(pts, q, k)
            np.testing.assert_allclose(
                np.sort(np.sum((pts[res[i]] - q) ** 2, axis=1)),
                np.sort(np.sum((pts[ref] - q) ** 2, axis=1)),
            )


def test_knn_batch_agrees_with_single(built):
    pts, idx = built
    rng = np.random.default_rng(7)
    qs = rng.random((6, 2))
    batch, _ = knn_query_batch(idx, qs, 16)
    for i, q in enumerate(qs):
        single, _ = knn_query(idx, q, 16)
        np.testing.assert_allclose(
            np.sort(np.sum((pts[batch[i]] - q) ** 2, axis=1)),
            np.sort(np.sum((pts[single] - q) ** 2, axis=1)),
        )
