"""Per-architecture smoke tests (deliverable f): REDUCED config of the same
family wiring — one forward/train step on CPU, asserting output shapes and
no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import all_configs
from repro.launch.train import reduced_config
from repro.launch.mesh import make_mesh, use_mesh
from repro.models import model as M
from repro.models.sharding import MeshAxes

ARCHS = sorted(all_configs())
B, S = 2, 64


@pytest.fixture(scope="module")
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def _batch(cfg, rng):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32
        )
        batch["tokens"] = batch["labels"] = toks[:, : S // 8]
    if cfg.frontend == "patch_stub":
        M.VLM_PATCH_TOKENS = 8
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, 8, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch, mesh):
    cfg = reduced_config(all_configs()[arch])
    rng = np.random.default_rng(hash(arch) % 2**31)
    batch = _batch(cfg, rng)
    params = M.init_params(cfg, jax.random.key(0), jnp.float32)
    axes = MeshAxes()
    with use_mesh(mesh):
        lg, _ = M.forward(params, cfg, batch, axes, mode="train")
        loss, grads = jax.value_and_grad(M.loss_fn)(params, cfg, batch, axes)
    seq = batch["tokens"].shape[1] + (
        8 if cfg.frontend == "patch_stub" else 0
    )
    assert lg.shape == (B, seq, cfg.padded_vocab)
    assert not bool(jnp.isnan(lg).any()), "NaN in logits"
    assert not bool(jnp.isnan(loss)), "NaN loss"
    assert 1.0 < float(loss) < 20.0, f"loss scale off: {float(loss)}"
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0, "degenerate gradients"


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_one_sgd_step_changes_params(arch, mesh):
    cfg = reduced_config(all_configs()[arch])
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    params = M.init_params(cfg, jax.random.key(1), jnp.float32)
    axes = MeshAxes()
    with use_mesh(mesh):
        grads = jax.grad(M.loss_fn)(params, cfg, batch, axes)
        new = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
        delta = sum(
            float(jnp.sum(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params))
        )
    assert delta > 0.0
