"""Fixture: inventoried read call issued with no lock at all.

Query entry points must hold at least the reader side of the table
lock, otherwise a concurrent compaction can renumber rows mid-scan.
"""


class DeviceQueryServer:
    def window(self, lo, hi):
        # BAD: neither .read() nor .write() dominates this call
        return self.dev.window_query_batch_jax(lo, hi)
