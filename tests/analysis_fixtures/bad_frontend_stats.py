"""Fixture: the pre-fix form of the frontend drop path.

Two racing finishers could both see ``req.done`` false and double-count
a drop; the stat bump and the terminal-state claim must be one atomic
section under ``self._mu``.  The guarded twin below must stay silent.
"""


class Frontend:
    def reject_racy(self, req):
        self.stats.rejected += 1  # BAD: stat bump outside self._mu
        req._event.set()

    def reject_claimed(self, req):
        with self._mu:
            self.stats.rejected += 1  # OK: claimed under the condition
            req._event.set()
