"""A deliberately empty tests corpus for the corpus-backed checkers
(fault-coverage, ref-twin).  Mentions no fault names and no ref twins,
so fixtures that need an uncovered name fail deterministically."""
