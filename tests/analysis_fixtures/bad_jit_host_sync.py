# analysis: jit-strict
"""Fixture: host synchronization on a traced value inside a jit root.

``float(...)`` on a traced array forces a device sync per call and
breaks tracing; shape arithmetic (static) is fine and must not flag.
"""

import jax
import jax.numpy as jnp


@jax.jit
def bad_mean(x):
    total = float(jnp.sum(x))  # BAD: host sync on a tracer
    return total / x.shape[0]  # OK: .shape is static


@jax.jit
def good_mean(x):
    return jnp.sum(x) / x.shape[0]
