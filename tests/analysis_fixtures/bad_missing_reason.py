"""Fixture: escape hatch used without a reason.

``unlocked-ok`` must carry a justification — a bare waiver is how
suppressions rot.
"""


class DeviceQueryServer:
    def swap_overlay(self, overlay):
        self.stream = overlay  # analysis: unlocked-ok
