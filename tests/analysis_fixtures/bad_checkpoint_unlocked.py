"""Fixture: the pre-fix form of ``DeviceQueryServer.checkpoint()``.

This is the literal bug class fixed in this PR: snapshotting without
quiescing writers lets a concurrent ``insert`` land between the overlay
serialization and the journal truncation — the record exists in neither
and is lost.  The checker flags the unguarded ``compact``/``truncate``
mutation calls.
"""


class DeviceQueryServer:
    def checkpoint(self, path):
        # BAD: no ``with self.table_lock.write():`` around the snapshot
        self.stream.compact()
        self.journal.truncate(path)
