"""Fixture: a registered failure point no test ever injects.

A fault nobody fires is a recovery path that has never executed;
registering one must ship an injection test in the same change.
"""

FAILURE_POINTS = (
    "fixture_uncovered_point",
)
