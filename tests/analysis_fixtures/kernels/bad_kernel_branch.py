"""Fixture: Python control flow on a traced value inside a Pallas
kernel body.

A ref load is a tracer — branching on it raises a ConcretizationError
under jit and silently miscompiles under interpret mode.  Use
``jnp.where`` / ``lax.select`` instead.
"""

from jax.experimental import pallas as pl  # noqa: F401


def _relu_kernel(x_ref, o_ref):
    v = x_ref[0]
    if v > 0.0:  # BAD: Python branch on a traced ref load
        o_ref[0] = v
    else:
        o_ref[0] = 0.0


def _copy_kernel(x_ref, o_ref):
    o_ref[0] = x_ref[0]  # OK: no host branching
