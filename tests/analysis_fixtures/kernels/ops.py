"""Fixture: a Pallas wrapper module with no sibling ``ref.py`` oracle.

Every public wrapper that reaches ``pallas_call`` must have a NumPy
reference twin exercised by a test; this module has none.
"""

from jax.experimental import pallas as pl


def _relu_kernel(x_ref, o_ref):
    o_ref[0] = x_ref[0]


def fused_relu(x):
    return pl.pallas_call(_relu_kernel, out_shape=x)(x)
