"""Fixture: the disciplined twin of the bad snippets — must stay
silent under every checker.

Journal before mutation, all of it inside one writer section; reads
under the reader lock; a justified escape hatch for the stats counter.
"""


class DeviceQueryServer:
    def ingest(self, p, rec):
        with self.table_lock.write():
            self.journal.append(rec)  # journal first ...
            self.stream.insert(p)     # ... then mutate

    def window(self, lo, hi):
        with self.table_lock.read():
            return self.dev.window_query_batch_jax(lo, hi)

    def bump(self):
        self.stats = None  # analysis: unlocked-ok(monotonic counter, torn reads acceptable)
