"""Fixture: state mutated before the journal append in the same writer
section (Rule A — the PR-9 bug class).

If the process dies between the mutation and the append, recovery
replays a journal that never saw the operation: silent data loss.
"""


class DeviceQueryServer:
    def ingest(self, p, rec):
        with self.table_lock.write():
            self.stream.insert(p)     # BAD: mutation first ...
            self.journal.append(rec)  # ... journal second
