"""Fixture: guarded attribute written outside a writer section.

``DeviceQueryServer.stream`` is inventoried shared state — publishing a
new overlay without ``with self.table_lock.write():`` races every
concurrent reader.
"""


class DeviceQueryServer:
    def swap_overlay(self, overlay):
        self.stream = overlay  # BAD: unlocked publish of shared state
