"""Fixture: journal append outside any writer section (Rule B).

A journal record written while another thread mutates the overlay can
serialize a state the index never held — replay then diverges.
"""


class DeviceQueryServer:
    def log_insert(self, rec):
        self.journal.append(rec)  # BAD: journal write with no writer lock
