"""Self-tests for the REPRO_SANITIZE runtime sanitizer.

The satellite contract: a thread that mutates a bound NodeTable without
the writer lock must trip the assertion, and a deliberate A->B / B->A
acquisition inversion must be reported by the deadlock detector rather
than hanging the suite.
"""

import threading
from contextlib import contextmanager

import numpy as np
import pytest

from repro.analysis import runtime as san
from repro.analysis.runtime import LockOrderError, SanitizerError
from repro.core.nodetable import NodeTable
from repro.serve.resilience import TableLock


@contextmanager
def sanitizer_on():
    prev = san.enable()
    san.reset()
    try:
        yield
    finally:
        san.reset()
        if not prev:
            san.disable()


def _table():
    return NodeTable(dim=2)


NO_ROWS = np.empty(0, dtype=np.int64)


def test_unlocked_mutation_from_thread_trips():
    with sanitizer_on():
        lock = TableLock("tbl")
        tbl = _table()
        san.bind(tbl, lock)
        errs = []

        def rogue():
            try:
                tbl.neutralize_rows(NO_ROWS)
            except SanitizerError as e:
                errs.append(e)

        t = threading.Thread(target=rogue)
        t.start()
        t.join()
        assert len(errs) == 1
        assert "writer lock" in str(errs[0])


def test_locked_mutation_passes():
    with sanitizer_on():
        lock = TableLock("tbl")
        tbl = _table()
        san.bind(tbl, lock)
        with lock.write():
            tbl.neutralize_rows(NO_ROWS)


def test_reader_lock_is_not_enough():
    with sanitizer_on():
        lock = TableLock("tbl")
        tbl = _table()
        san.bind(tbl, lock)
        with lock.read():
            with pytest.raises(SanitizerError):
                tbl.neutralize_rows(NO_ROWS)


def test_unbound_table_is_exempt():
    # boot-time construction mutates freely before publication
    with sanitizer_on():
        _table().neutralize_rows(NO_ROWS)


def test_disabled_sanitizer_is_a_noop():
    lock = TableLock("tbl")
    tbl = _table()
    san.bind(tbl, lock)
    assert not san.enabled() or True  # env-enabled runs still pass below
    if not san.enabled():
        tbl.neutralize_rows(NO_ROWS)  # must not raise when off


def test_lock_order_inversion_reported():
    with sanitizer_on():
        a = TableLock("lock_a")
        b = TableLock("lock_b")
        # establish the order a -> b
        with a.write():
            with b.write():
                pass
        # the inversion b -> a is a potential deadlock
        with b.write():
            with pytest.raises(LockOrderError, match="inversion"):
                with a.write():
                    pass


def test_same_lock_reentry_reported_not_deadlocked():
    # TableLock is not reentrant: nested write() self-deadlocks.  The
    # sanitizer raises before blocking instead of hanging the suite.
    with sanitizer_on():
        a = TableLock("lock_a")
        with a.write():
            with pytest.raises(LockOrderError, match="re-entrant"):
                with a.write():
                    pass


def test_mixed_read_write_order_tracked():
    with sanitizer_on():
        a = TableLock("lock_a")
        b = TableLock("lock_b")
        with a.read():
            with b.write():
                pass
        with b.write():
            with pytest.raises(LockOrderError):
                with a.read():
                    pass
