"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core import PageStore, bulk_load, window_oracle, window_query
from repro.core.hilbert import hilbert_rank
from repro.core.splittree import build_group_median_tree


@st.composite
def point_sets(draw, max_n=4000, d_max=4):
    n = draw(st.integers(min_value=400, max_value=max_n))
    d = draw(st.integers(min_value=2, max_value=d_max))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["uniform", "gauss", "skew", "dup"]))
    if kind == "uniform":
        pts = rng.random((n, d))
    elif kind == "gauss":
        pts = rng.normal(0.5, 0.2, (n, d))
    elif kind == "skew":
        pts = rng.random((n, d)) ** 3
    else:  # heavy coordinate duplication (degenerate medians)
        pts = rng.integers(0, 12, (n, d)).astype(np.float64) / 12.0
    return pts.astype(np.float64)


@given(point_sets())
@settings(max_examples=12, deadline=None)
def test_fmbi_partition_is_exact(pts):
    """Every point lands in exactly one leaf; MBBs contain their points."""
    idx = bulk_load(pts, 250)
    rows = np.concatenate([l.point_idx for l in idx.root.iter_leaves()])
    assert len(rows) == len(pts)
    assert len(np.unique(rows)) == len(pts)
    for leaf in idx.root.iter_leaves():
        sub = pts[leaf.point_idx]
        assert np.all(sub >= leaf.mbb[0] - 1e-12)
        assert np.all(sub <= leaf.mbb[1] + 1e-12)


@given(point_sets(max_n=2500), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_window_query_equals_oracle(pts, qseed):
    idx = bulk_load(pts, 250)
    rng = np.random.default_rng(qseed)
    d = pts.shape[1]
    c = rng.random(d)
    w = rng.uniform(0.01, 0.3)
    res, _ = window_query(idx, c - w, c + w)
    ref = window_oracle(pts, c - w, c + w)
    assert sorted(res.tolist()) == sorted(ref.tolist())


@given(point_sets(max_n=2000))
@settings(max_examples=8, deadline=None)
def test_group_median_tree_routes_to_balanced_groups(pts):
    from repro.core.pagestore import leaf_capacity

    d = pts.shape[1]
    c_l = leaf_capacity(d)
    groups = 4
    trim = (len(pts) // (groups * c_l)) * groups * c_l
    if trim < groups * c_l:
        return  # not enough points for one page per group
    gp = trim // (groups * c_l)
    tree, _, assign = build_group_median_tree(pts[:trim], groups, gp, c_l)
    counts = np.bincount(assign, minlength=groups)
    # exact equality by construction (split at page-group boundaries)
    assert np.all(counts == trim // groups)
    # routing agreement: the tree sends sample points to their groups.
    # Points tied with a split value all route left while the rank split
    # may have assigned some right — with heavily-duplicated coordinates
    # (the 'dup' strategy) whole runs of ties sit on the boundary, so the
    # bound is loose; index correctness is unaffected (Step 2 adjusts MBBs).
    routed = tree.route(pts[:trim])
    agree = (routed == assign).mean()
    assert agree > 0.75


@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
@settings(max_examples=10, deadline=None)
def test_hilbert_rank_locality(seed, d):
    """Neighbors on the curve are near in space (weak locality property):
    consecutive ranked points are closer on average than random pairs."""
    rng = np.random.default_rng(seed)
    pts = rng.random((800, d))
    order = np.argsort(hilbert_rank(pts))
    sorted_pts = pts[order]
    consec = np.linalg.norm(np.diff(sorted_pts, axis=0), axis=1).mean()
    # random pairs: two INDEPENDENT permutations (using one permutation
    # against its shift just re-pairs consecutive rows)
    p1, p2 = rng.permutation(800), rng.permutation(800)
    rand = np.linalg.norm(sorted_pts[p1] - sorted_pts[p2], axis=1).mean()
    assert consec < rand * 0.8


@given(point_sets(max_n=1500))
@settings(max_examples=8, deadline=None)
def test_io_accounting_nonnegative_and_bounded(pts):
    store = PageStore(250)
    bulk_load(pts, 250, store)
    from repro.core.pagestore import leaf_capacity

    p = -(-len(pts) // leaf_capacity(pts.shape[1]))
    assert store.stats.reads >= p  # at least one full scan
    # scan-based: far below even ONE external sort pass of log(P) rounds
    assert store.stats.total < 12 * p + 3000
