"""Device query engine: parity with the NumPy NodeTable engine + edge cases.

The parity contract (see ``core/queries_jax.py``): for float32-representable
inputs the compiled engine returns exactly the NumPy engine's result ids —
windows as sets (order unspecified), k-NN as ascending-distance sequences
(identical whenever distances are unique; under exact ties the id choice at
the k-th boundary may differ, so tie-heavy tests compare distances).  All
test data is generated float32-representable for that reason.
"""
import numpy as np
import pytest

from repro.core import (
    AMBI,
    PageStore,
    bulk_load,
    knn_oracle,
    knn_query,
    knn_query_batch,
    window_oracle,
    window_query,
    window_query_batch,
)
from repro.core import queries_jax as QJ
from repro.core.queries_jax import (
    DeviceTable,
    knn_query_batch_jax,
    window_query_batch_jax,
)
from repro.serve.engine import DeviceQueryServer

try:  # optional dev dependency (see requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _f32_points(n, d, seed, kind="uniform"):
    """Float32-representable coordinates (stored as float64)."""
    rng = np.random.default_rng(seed)
    if kind == "skew":
        pts = rng.random((n, d)) ** 3
    elif kind == "grid":  # heavy duplication, exact f32 arithmetic
        pts = rng.integers(0, 48, (n, d)) / np.float64(64.0)
    else:
        pts = rng.random((n, d))
    return pts.astype(np.float32).astype(np.float64)


def _build(pts, M=250):
    return bulk_load(pts, M, PageStore(M))


def _knn_check(pts, q, got, want, k):
    """got/want are id arrays; require identical distance sequences and
    id agreement wherever the oracle distances are unique."""
    dg = np.sort(np.sum((pts[got] - q) ** 2, axis=1))
    dw = np.sort(np.sum((pts[want] - q) ** 2, axis=1))
    np.testing.assert_array_equal(dg, dw)
    if len(np.unique(dw)) == len(dw):  # no ties: ids must match exactly
        assert np.array_equal(np.sort(got), np.sort(want))


# --------------------------------------------------------------------------
# randomized parity: FMBI workloads (fixed seeds)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind,d,seed", [
    ("uniform", 2, 0), ("uniform", 3, 1), ("skew", 2, 2), ("skew", 4, 3),
])
def test_window_parity_fmbi(kind, d, seed):
    pts = _f32_points(6000, d, seed, kind)
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    rng = np.random.default_rng(seed + 100)
    centers = rng.random((24, d)).astype(np.float32).astype(np.float64)
    widths = rng.choice([0.01, 0.05, 0.2, 0.6], size=(24, 1))
    los = (centers - widths).astype(np.float32).astype(np.float64)
    his = (centers + widths).astype(np.float32).astype(np.float64)
    want, _ = window_query_batch(idx, los, his)
    got = window_query_batch_jax(dev, los, his)
    for i in range(24):
        assert np.array_equal(np.sort(got[i]), np.sort(want[i]))
        assert np.array_equal(
            np.sort(got[i]), window_oracle(pts, los[i], his[i])
        )


@pytest.mark.parametrize("k,seed", [(1, 0), (8, 1), (32, 2)])
def test_knn_parity_fmbi(k, seed):
    pts = _f32_points(6000, 2, seed)
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    rng = np.random.default_rng(seed + 200)
    qs = rng.random((24, 2)).astype(np.float32).astype(np.float64)
    want, _ = knn_query_batch(idx, qs, k)
    got = knn_query_batch_jax(dev, qs, k)
    for i in range(24):
        # continuous data, fixed seeds: ascending-distance ids identical
        assert np.array_equal(got[i], want[i])
        assert np.array_equal(got[i], knn_oracle(pts, qs[i], k))


# --------------------------------------------------------------------------
# randomized parity: AMBI-snapshot workloads
# --------------------------------------------------------------------------
def _refined_ambi(pts, M=250):
    ambi = AMBI(pts, M)
    ambi.window(np.zeros(pts.shape[1]), np.ones(pts.shape[1]))
    assert ambi.is_fully_refined()
    return ambi


def test_parity_ambi_snapshot(tmp_path):
    """AMBI refines on demand (grafted rows are not level-contiguous);
    its snapshot must lay out and answer identically."""
    pts = _f32_points(8000, 2, 7, "skew")
    ambi = _refined_ambi(pts)
    snap = tmp_path / "ambi.npz"
    ambi.index.save(snap)

    srv = DeviceQueryServer.from_snapshot(snap)
    rng = np.random.default_rng(8)
    centers = rng.random((16, 2)).astype(np.float32).astype(np.float64)
    los = (centers - 0.05).astype(np.float32).astype(np.float64)
    his = (centers + 0.05).astype(np.float32).astype(np.float64)
    want, _ = window_query_batch(ambi.index, los, his)
    got = srv.window(los, his)
    for i in range(16):
        assert np.array_equal(np.sort(got[i]), np.sort(want[i]))
    qs = rng.random((16, 2)).astype(np.float32).astype(np.float64)
    wantk, _ = knn_query_batch(ambi.index, qs, 8)
    gotk = srv.knn(qs, 8)
    for i in range(16):
        assert np.array_equal(gotk[i], wantk[i])


def test_unrefined_table_is_rejected():
    pts = _f32_points(4000, 2, 3)
    ambi = AMBI(pts, 250)  # nothing refined yet
    with pytest.raises(ValueError, match="fully refined"):
        DeviceTable.from_table(ambi.table, pts)


# --------------------------------------------------------------------------
# Pallas kernel path (interpret mode on CPU)
# --------------------------------------------------------------------------
def test_kernel_path_matches_jnp_path():
    pts = _f32_points(3000, 2, 11)
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    rng = np.random.default_rng(12)
    centers = rng.random((8, 2)).astype(np.float32).astype(np.float64)
    los, his = centers - 0.08, centers + 0.08
    qs = rng.random((8, 2)).astype(np.float32).astype(np.float64)
    w_jnp = window_query_batch_jax(dev, los, his, use_kernel=False)
    w_ker = window_query_batch_jax(dev, los, his, use_kernel=True)
    k_jnp = knn_query_batch_jax(dev, qs, 8, use_kernel=False)
    k_ker = knn_query_batch_jax(dev, qs, 8, use_kernel=True)
    for i in range(8):
        assert np.array_equal(np.sort(w_jnp[i]), np.sort(w_ker[i]))
        assert np.array_equal(k_jnp[i], k_ker[i])


# --------------------------------------------------------------------------
# edge cases: k >= n, duplicates, zero-volume windows, single-query batches
# (parity against the oracles and the single-query engines)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("k", [600, 1000])
def test_knn_k_geq_n(k):
    pts = _f32_points(600, 2, 5)
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    qs = np.random.default_rng(6).random((4, 2)).astype(
        np.float32).astype(np.float64)
    want, _ = knn_query_batch(idx, qs, k)
    got = knn_query_batch_jax(dev, qs, k)
    for i in range(4):
        assert len(got[i]) == len(pts)  # every point, ascending distance
        _knn_check(pts, qs[i], got[i], want[i], k)
        _knn_check(pts, qs[i], got[i], knn_oracle(pts, qs[i], k), k)
        single, _ = knn_query(idx, qs[i], k)
        _knn_check(pts, qs[i], got[i], single, k)


def test_duplicate_coordinates():
    """Grid-quantized data: many exactly coincident points and exact-tie
    distances.  Distances must agree everywhere; ids wherever unique."""
    pts = _f32_points(5000, 2, 9, "grid")
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    rng = np.random.default_rng(10)
    qs = (rng.integers(0, 48, (8, 2)) / 64.0).astype(np.float64)
    want, _ = knn_query_batch(idx, qs, 16)
    got = knn_query_batch_jax(dev, qs, 16)
    for i in range(8):
        _knn_check(pts, qs[i], got[i], want[i], 16)
        single, _ = knn_query(idx, qs[i], 16)
        _knn_check(pts, qs[i], got[i], single, 16)
    # windows have no tie ambiguity even on duplicated coordinates
    los = qs - 3 / 64.0
    his = qs + 3 / 64.0
    wantw, _ = window_query_batch(idx, los, his)
    gotw = window_query_batch_jax(dev, los, his)
    for i in range(8):
        assert np.array_equal(np.sort(gotw[i]), np.sort(wantw[i]))
        assert np.array_equal(
            np.sort(gotw[i]), window_oracle(pts, los[i], his[i])
        )


def test_zero_volume_windows():
    """lo == hi windows: exactly the points at that coordinate."""
    pts = _f32_points(4000, 2, 13, "grid")
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    los = np.concatenate([pts[:3], [[0.9999, 0.9999]]])  # 3 hits + 1 miss
    his = los.copy()
    want, _ = window_query_batch(idx, los, his)
    got = window_query_batch_jax(dev, los, his)
    for i in range(4):
        assert np.array_equal(np.sort(got[i]), np.sort(want[i]))
        assert np.array_equal(
            np.sort(got[i]), window_oracle(pts, los[i], his[i])
        )
        single, _ = window_query(idx, los[i], his[i])
        assert np.array_equal(np.sort(got[i]), np.sort(single))
    assert len(got[0]) >= 1 and len(got[3]) == 0


def test_single_query_batches():
    pts = _f32_points(3000, 3, 14)
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    q = np.asarray([[0.5, 0.5, 0.5]])
    lo, hi = q - 0.1, q + 0.1
    got = window_query_batch_jax(dev, lo, hi)
    assert len(got) == 1
    single, _ = window_query(idx, lo[0], hi[0])
    assert np.array_equal(np.sort(got[0]), np.sort(single))
    wb, _ = window_query_batch(idx, lo, hi)
    assert np.array_equal(np.sort(wb[0]), np.sort(got[0]))
    gotk = knn_query_batch_jax(dev, q, 5)
    assert len(gotk) == 1
    singlek, _ = knn_query(idx, q[0], 5)
    assert np.array_equal(gotk[0], singlek)
    kb, _ = knn_query_batch(idx, q, 5)
    assert np.array_equal(kb[0], gotk[0])


def test_empty_result_windows():
    pts = _f32_points(3000, 2, 15)
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)
    los = np.full((3, 2), 2.0)  # entirely outside the data domain
    his = los + 0.1
    got = window_query_batch_jax(dev, los, his)
    assert all(len(g) == 0 for g in got)


# --------------------------------------------------------------------------
# hypothesis: randomized workloads (grid coordinates keep f32 exact)
# --------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _IDX_CACHE = {}

    def _cached(seed):
        if seed not in _IDX_CACHE:
            pts = _f32_points(4000, 2, seed, "grid")
            idx = _build(pts)
            _IDX_CACHE[seed] = (pts, idx, DeviceTable.from_index(idx))
        return _IDX_CACHE[seed]

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2),
        qseed=st.integers(0, 10_000),
        w=st.integers(1, 12),
        k=st.integers(1, 24),
    )
    def test_hypothesis_parity(seed, qseed, w, k):
        pts, idx, dev = _cached(seed)
        rng = np.random.default_rng(qseed)
        centers = rng.integers(0, 48, (6, 2)) / 64.0
        los = centers - w / 64.0
        his = centers + w / 64.0
        want, _ = window_query_batch(idx, los, his)
        got = window_query_batch_jax(dev, los, his)
        for i in range(6):
            assert np.array_equal(np.sort(got[i]), np.sort(want[i]))
        wantk, _ = knn_query_batch(idx, centers, k)
        gotk = knn_query_batch_jax(dev, centers, k)
        for i in range(6):
            _knn_check(pts, centers[i], gotk[i], wantk[i], k)


# --------------------------------------------------------------------------
# serving: microbatching + compile-variant bounding
# --------------------------------------------------------------------------
def test_device_server_microbatching():
    pts = _f32_points(6000, 2, 21)
    idx = _build(pts)
    srv = DeviceQueryServer.from_index(idx, microbatch=32)
    rng = np.random.default_rng(22)
    centers = rng.random((100, 2)).astype(np.float32).astype(np.float64)
    los, his = centers - 0.04, centers + 0.04
    got = srv.window(los, his)
    assert len(got) == 100
    assert srv.stats.microbatches == 4  # ceil(100 / 32)
    want, _ = window_query_batch(idx, los, his)
    for i in range(100):
        assert np.array_equal(np.sort(got[i]), np.sort(want[i]))
    gotk = srv.knn(centers[:50], 8)
    wantk, _ = knn_query_batch(idx, centers[:50], 8)
    for i in range(50):
        assert np.array_equal(gotk[i], wantk[i])
    assert srv.stats.queries == 150


def test_compile_variants_bounded_across_workload_drift():
    """Growing window widths / batch sizes must not grow compilations
    without bound: a repeated sweep adds zero retraces."""
    pts = _f32_points(6000, 2, 31)
    idx = _build(pts)
    dev = DeviceTable.from_index(idx)

    def sweep():
        rng = np.random.default_rng(32)  # same workload every sweep
        for q, w in [(3, 0.01), (5, 0.03), (7, 0.08), (8, 0.15), (6, 0.3)]:
            centers = rng.random((q, 2)).astype(np.float32)
            window_query_batch_jax(dev, centers - w, centers + w)
            knn_query_batch_jax(dev, centers, 8)

    sweep()  # warm every bucket the workload can reach
    before = dict(QJ.TRACE_COUNTS)
    sweep()
    sweep()
    assert QJ.TRACE_COUNTS == before


# --------------------------------------------------------------------------
# PR-7 fused path: parity, env pin, and bounded recompiles
# --------------------------------------------------------------------------
@pytest.mark.parametrize("compressed", [False, True])
def test_fused_engine_matches_unfused(compressed):
    """The fused (on-device packed) pipeline is id-identical to the
    first-generation path on the same export — window sets and k-NN
    sequences — including a starved k-NN budget that must escalate."""
    pts = _f32_points(5000, 3, 71, kind="skew")
    idx = _build(pts)
    dev = DeviceTable.from_index(idx, compressed=compressed)
    rng = np.random.default_rng(72)
    ctr = rng.random((19, 3))  # odd batch: pow2 padding rows in play
    los, his = ctr - 0.06, ctr + 0.06
    w0 = window_query_batch_jax(dev, los, his, fused=False)
    w1 = window_query_batch_jax(dev, los, his, fused=True)
    for a, b in zip(w0, w1):
        assert set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
    k0 = knn_query_batch_jax(dev, ctr, 10, fused=False,
                             n_candidate_leaves=1)
    k1 = knn_query_batch_jax(dev, ctr, 10, fused=True,
                             n_candidate_leaves=1)
    for a, b in zip(k0, k1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_default_env_pin(monkeypatch):
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    assert QJ._fused_default() is True
    monkeypatch.setenv("REPRO_FUSED", "0")
    assert QJ._fused_default() is False
    monkeypatch.setenv("REPRO_FUSED", "1")
    assert QJ._fused_default() is True


def test_fused_recompile_bounded():
    """The fused path's pow2 bucketing keeps compiled variants bounded:
    a repeated mixed sweep (both layouts, drifting widths and batch
    sizes, escalating k-NN budgets) adds zero retraces after warmup —
    including the new pair-pack / id-pack / pending-selection jits."""
    pts = _f32_points(6000, 2, 73)
    idx = _build(pts)
    devs = [DeviceTable.from_index(idx, compressed=c)
            for c in (False, True)]

    def sweep():
        rng = np.random.default_rng(74)  # same workload every sweep
        for dev in devs:
            for q, w in [(3, 0.01), (5, 0.05), (8, 0.2), (6, 0.4)]:
                centers = rng.random((q, 2)).astype(np.float32)
                window_query_batch_jax(dev, centers - w, centers + w,
                                       fused=True)
                knn_query_batch_jax(dev, centers, 8, fused=True,
                                    n_candidate_leaves=1)

    sweep()  # warm every bucket the workload can reach
    before = QJ.trace_counts()
    sweep()
    sweep()
    assert QJ.trace_counts() == before


def test_fused_partial_export_cold_mask():
    """return_cold on the fused path surfaces the same cold-hit rows as
    the first-generation path on a partial export."""
    pts = _f32_points(4000, 2, 75)
    ambi = AMBI(pts, 250)
    c = np.asarray([0.5, 0.5])
    ambi.window(c - 0.05, c + 0.05)  # refine one hotspot only
    dev = DeviceTable.from_table(ambi.table, ambi.points, partial=True)
    rng = np.random.default_rng(76)
    ctr = rng.random((9, 2))
    los, his = ctr - 0.08, ctr + 0.08
    r0, cold0 = window_query_batch_jax(dev, los, his, fused=False,
                                       return_cold=True)
    r1, cold1 = window_query_batch_jax(dev, los, his, fused=True,
                                       return_cold=True)
    np.testing.assert_array_equal(np.asarray(cold0), np.asarray(cold1))
    for a, b in zip(r0, r1):
        assert set(np.asarray(a).tolist()) == set(np.asarray(b).tolist())
