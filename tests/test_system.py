"""End-to-end system behaviour: the paper's full pipeline — build, query,
adapt, distribute — exercised as one scenario per test."""
import numpy as np

from repro.core import (
    AMBI,
    PageStore,
    bulk_load,
    knn_oracle,
    knn_query,
    leaf_stats,
    window_oracle,
    window_query,
)
from repro.core.datasets import nycyt_like, osm_like
from repro.core.distributed import parallel_bulk_load, parallel_window_cost


def test_full_lifecycle_build_query_workload():
    """One operator story: bulk load a live dataset, serve a mixed query
    stream, and verify the cheap-construction / fast-query contract."""
    pts = osm_like(150_000, seed=42)
    M = 300
    store = PageStore(M)
    idx = bulk_load(pts, M, store)
    build_io = store.stats.total

    rng = np.random.default_rng(0)
    query_io = 0
    for i in range(40):
        if i % 2 == 0:
            c = rng.random(2)
            res, io = window_query(idx, c - 0.02, c + 0.02)
            ref = window_oracle(pts, c - 0.02, c + 0.02)
            assert sorted(res.tolist()) == sorted(ref.tolist())
        else:
            q = rng.random(2)
            res, io = knn_query(idx, q, 32)
            ref = knn_oracle(pts, q, 32)
            assert np.allclose(
                np.sort(np.sum((pts[res] - q) ** 2, axis=1)),
                np.sort(np.sum((pts[ref] - q) ** 2, axis=1)),
            )
        query_io += io.total
    # the paper's contract: construction dominates; each query is cheap
    assert query_io / 40 < build_io / 20
    ls = leaf_stats(idx)
    # one partial page per subspace: fill rises toward 1.0 as N/M grows
    # (paper scale: 1e9 points -> ~0.99; here 150k -> ~0.72)
    assert ls.avg_fill > 0.65


def test_adaptive_beats_full_build_then_stays_exact():
    pts = osm_like(150_000, seed=43)
    M = 300
    ambi = AMBI(pts, M)
    rng = np.random.default_rng(1)
    adaptive_cost = 0
    for _ in range(15):
        c = rng.random(2) * 0.1 + 0.5
        _, io = ambi.window(c - 0.02, c + 0.02)
        adaptive_cost += io.total
    store = PageStore(M)
    bulk_load(pts, M, store)
    assert adaptive_cost < store.stats.total  # paper Fig 8
    # the partial index still answers global queries exactly
    res, _ = ambi.window(np.array([-1, -1.0]), np.array([2, 2.0]))
    assert len(res) == len(pts)


def test_distributed_end_to_end_5d():
    pts = nycyt_like(80_000, d=5, seed=44)
    build = parallel_bulk_load(pts, m=4, buffer_pages=600)
    assert sum(len(i.points) for i in build.indexes) == len(pts)
    sizes = [len(i.points) for i in build.indexes]
    assert max(sizes) / (sum(sizes) / 4) < 1.5  # balanced servers
    rng = np.random.default_rng(2)
    hits = 0
    for _ in range(10):
        c = rng.random(5)
        n, cost = parallel_window_cost(build, c - 0.15, c + 0.15)
        ref = int(np.sum(np.all((pts >= c - 0.15) & (pts <= c + 0.15),
                                axis=1)))
        assert n == ref
        hits += n
    assert hits > 0
