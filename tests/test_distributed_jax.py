"""Sharded device engine: parity with the single-table engines + edge cases.

The parity harness (``tests/engines.py``) runs the NumPy NodeTable engine,
the single DeviceTable engine, and the m-shard engine for m in {1, 2, 4}
over the same FMBI and grafted-AMBI tables and asserts id-identical
results — the same pinning discipline ``test_flat_queries.py`` applied to
PR 2 and ``test_queries_jax.py`` to PR 3.  Edge cases: m=1, shards with
zero qualifying leaves, k >= points-per-shard, queries straddling shard
boundaries, duplicate coordinates.  The shard_map collective rounds run in
a subprocess with forced virtual devices (CI also runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and Pallas
interpret mode).
"""
import subprocess
import sys

import numpy as np
import pytest

from engines import (
    assert_knn_parity,
    assert_window_parity,
    build_fmbi,
    build_grafted_ambi,
    engine_suite,
    f32_points,
)
from repro.core import distributed_jax as DJ
from repro.core.distributed import parallel_bulk_load
from repro.core.distributed_jax import (
    ShardedDeviceTable,
    knn_query_batch_sharded,
    window_query_batch_sharded,
)
from repro.core.geometry import boxes_intersect_windows
from repro.core.queries_jax import knn_query_batch_jax, window_query_batch_jax
from repro.serve.engine import DeviceQueryServer

try:  # optional dev dependency (see requirements-dev.txt)
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _windows(rng, d, n, width):
    centers = rng.random((n, d)).astype(np.float32).astype(np.float64)
    return centers - width, centers + width, centers


# --------------------------------------------------------------------------
# parity harness: all engines over the same tables (acceptance criterion)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kind,d,seed", [
    ("uniform", 2, 0), ("uniform", 3, 1), ("skew", 2, 2),
])
def test_parity_fmbi(kind, d, seed):
    pts = f32_points(6000, d, seed, kind)
    engines = engine_suite(build_fmbi(pts))
    rng = np.random.default_rng(seed + 50)
    los, his, centers = _windows(rng, d, 16, 0.06)
    assert_window_parity(engines, los, his)
    assert_knn_parity(engines, pts, centers, 10)


def test_parity_grafted_ambi():
    pts = f32_points(8000, 2, 7, "skew")
    engines = engine_suite(build_grafted_ambi(pts))
    rng = np.random.default_rng(8)
    los, his, centers = _windows(rng, 2, 16, 0.05)
    assert_window_parity(engines, los, his)
    assert_knn_parity(engines, pts, centers, 8)


def test_duplicate_coordinates():
    """Grid-quantized data: coincident points and exact-tie distances.
    Distances must agree everywhere, ids wherever unique."""
    pts = f32_points(5000, 2, 9, "grid")
    engines = engine_suite(build_fmbi(pts))
    rng = np.random.default_rng(10)
    qs = (rng.integers(0, 48, (8, 2)) / 64.0).astype(np.float64)
    assert_window_parity(engines, qs - 3 / 64.0, qs + 3 / 64.0)
    assert_knn_parity(engines, pts, qs, 16, ids_exact=False)


# --------------------------------------------------------------------------
# edge cases
# --------------------------------------------------------------------------
def test_m1_identical_to_single_table_engine():
    pts = f32_points(4000, 2, 3)
    idx = build_fmbi(pts)
    sdev = ShardedDeviceTable.from_index(idx, 1)
    assert sdev.m == 1
    from repro.core.queries_jax import DeviceTable

    dev = DeviceTable.from_index(idx)
    rng = np.random.default_rng(4)
    los, his, centers = _windows(rng, 2, 8, 0.08)
    for a, b in zip(window_query_batch_sharded(sdev, los, his),
                    window_query_batch_jax(dev, los, his)):
        assert np.array_equal(np.sort(a), np.sort(b))
    for a, b in zip(knn_query_batch_sharded(sdev, centers, 7),
                    knn_query_batch_jax(dev, centers, 7)):
        assert np.array_equal(a, b)


def test_window_fans_out_only_to_qualified_shards(monkeypatch):
    """A shard whose subspace MBB misses every query box must receive no
    dispatch at all (zero qualifying leaves => zero work)."""
    pts = f32_points(6000, 2, 11)
    sdev = ShardedDeviceTable.from_index(build_fmbi(pts), 4)
    # narrow boxes just inside shard 0's subspace corner
    lo0 = sdev.shard_lo[0].astype(np.float64)
    los = np.tile(lo0, (3, 1))
    his = los + 1e-4
    hit = boxes_intersect_windows(sdev.shard_lo, sdev.shard_hi,
                                  los.astype(np.float32),
                                  his.astype(np.float32))
    assert not hit.all(), "boxes must miss at least one shard"
    dispatched = []
    real = DJ.window_query_batch_jax

    def spy(dev, *a, **kw):
        dispatched.append(id(dev))
        return real(dev, *a, **kw)

    monkeypatch.setattr(DJ, "window_query_batch_jax", spy)
    got = window_query_batch_sharded(sdev, los, his)
    probed = {id(sdev.shards[s]) for s in range(4) if hit[:, s].any()}
    assert set(dispatched) == probed
    for i in range(3):
        oracle = np.flatnonzero(
            np.all((pts >= los[i]) & (pts <= his[i]), axis=1)
        )
        assert np.array_equal(np.sort(got[i]), oracle)


def test_windows_entirely_outside_all_shards():
    pts = f32_points(3000, 2, 15)
    sdev = ShardedDeviceTable.from_index(build_fmbi(pts), 4)
    los = np.full((3, 2), 2.0)
    got = window_query_batch_sharded(sdev, los, los + 0.1)
    assert all(len(g) == 0 for g in got)


def test_k_geq_points_per_shard():
    """k larger than any single shard forces the +inf pruning radius and
    full escalation; results must still be the exact global top-k."""
    pts = f32_points(2000, 2, 5)
    idx = build_fmbi(pts)
    engines = engine_suite(idx, ms=(2, 4))
    qs = np.random.default_rng(6).random((4, 2)).astype(
        np.float32).astype(np.float64)
    for k in (600, 1200, 2500):  # > n/4, > n/2, > n
        ref = assert_knn_parity(engines, pts, qs, k, ids_exact=False)
        want_len = min(k, len(pts))
        assert all(len(r) == want_len for r in ref)


def test_queries_straddling_shard_boundaries():
    """Wide windows and centroid k-NN hit several shards at once."""
    pts = f32_points(6000, 2, 12)
    engines = engine_suite(build_fmbi(pts))
    center = np.float64(np.float32(0.5))
    los = np.array([[center - 0.4, center - 0.4],
                    [0.0, center - 0.01],
                    [center - 0.01, 0.0]])
    his = np.array([[center + 0.4, center + 0.4],
                    [1.0, center + 0.01],
                    [center + 0.01, 1.0]])
    assert_window_parity(engines, los, his)
    qs = np.array([[center, center], [center, 0.1], [0.9, center]])
    assert_knn_parity(engines, pts, qs, 24)
    # the wide window really does straddle: >1 shard qualifies
    for eng in engines:
        if getattr(eng, "sdev", None) is not None and eng.sdev.m > 1:
            hit = boxes_intersect_windows(
                eng.sdev.shard_lo, eng.sdev.shard_hi,
                los.astype(np.float32), his.astype(np.float32))
            assert hit[0].sum() > 1


def test_sharded_kernel_path_matches_jnp_path():
    """The Pallas leaf kernels behind each shard (interpret mode on CPU CI)
    return the jnp path's results through the distributed rounds too."""
    pts = f32_points(3000, 2, 11)
    sdev = ShardedDeviceTable.from_index(build_fmbi(pts), 2)
    rng = np.random.default_rng(12)
    los, his, centers = _windows(rng, 2, 6, 0.08)
    w_jnp = window_query_batch_sharded(sdev, los, his, use_kernel=False)
    w_ker = window_query_batch_sharded(sdev, los, his, use_kernel=True)
    k_jnp = knn_query_batch_sharded(sdev, centers, 8, use_kernel=False)
    k_ker = knn_query_batch_sharded(sdev, centers, 8, use_kernel=True)
    for i in range(6):
        assert np.array_equal(np.sort(w_jnp[i]), np.sort(w_ker[i]))
        assert np.array_equal(k_jnp[i], k_ker[i])


# --------------------------------------------------------------------------
# hypothesis: randomized workloads (grid coordinates keep f32 exact)
# --------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _SUITE_CACHE = {}

    def _cached(seed):
        if seed not in _SUITE_CACHE:
            pts = f32_points(4000, 2, seed, "grid")
            _SUITE_CACHE[seed] = (pts, engine_suite(build_fmbi(pts)))
        return _SUITE_CACHE[seed]

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 1),
        qseed=st.integers(0, 10_000),
        w=st.integers(1, 12),
        k=st.integers(1, 24),
    )
    def test_hypothesis_parity(seed, qseed, w, k):
        pts, engines = _cached(seed)
        rng = np.random.default_rng(qseed)
        centers = rng.integers(0, 48, (5, 2)) / 64.0
        assert_window_parity(engines, centers - w / 64.0, centers + w / 64.0)
        assert_knn_parity(engines, pts, centers, k, ids_exact=False)


# --------------------------------------------------------------------------
# one representation: host m-server build and TPU build feed one engine
# --------------------------------------------------------------------------
def test_from_parallel_build_serves_globally():
    """The Figure-11 m-server simulation ships straight into the sharded
    device engine (per-server subtrees become the shards verbatim)."""
    pts = f32_points(20_000, 2, 31)
    build = parallel_bulk_load(pts, m=4, buffer_pages=600)
    sdev = ShardedDeviceTable.from_parallel_build(build, pts)
    assert sdev.m == 4
    assert sdev.n_points == len(pts)
    rng = np.random.default_rng(3)
    los, his, centers = _windows(rng, 2, 8, 0.04)
    got = window_query_batch_sharded(sdev, los, his)
    for i in range(8):
        oracle = np.flatnonzero(
            np.all((pts >= los[i]) & (pts <= his[i]), axis=1)
        )
        assert np.array_equal(np.sort(got[i]), oracle)
    gotk = knn_query_batch_sharded(sdev, centers, 12)
    for i in range(8):
        d2 = np.sum((pts - centers[i]) ** 2, axis=1)
        want = np.sort(d2)[:12]
        np.testing.assert_array_equal(
            np.sort(d2[gotk[i]]), want
        )


# --------------------------------------------------------------------------
# serving: DeviceQueryServer shards= mode
# --------------------------------------------------------------------------
def test_device_server_sharded_mode():
    pts = f32_points(6000, 2, 21)
    idx = build_fmbi(pts)
    srv1 = DeviceQueryServer.from_index(idx, microbatch=32)
    srv4 = DeviceQueryServer.from_index(idx, microbatch=32, shards=4)
    assert srv4.stats.shards == 4 and srv1.stats.shards == 1
    rng = np.random.default_rng(22)
    centers = rng.random((80, 2)).astype(np.float32).astype(np.float64)
    los, his = centers - 0.04, centers + 0.04
    w1, w4 = srv1.window(los, his), srv4.window(los, his)
    for a, b in zip(w1, w4):
        assert np.array_equal(np.sort(a), np.sort(b))
    k1, k4 = srv1.knn(centers[:40], 8), srv4.knn(centers[:40], 8)
    for a, b in zip(k1, k4):
        assert np.array_equal(a, b)
    assert srv4.stats.microbatches == 3 + 2  # ceil(80/32) + ceil(40/32)
    assert srv4.stats.queries == 120


# --------------------------------------------------------------------------
# shard_map collective rounds (forced virtual devices, subprocess so the
# device count never leaks into this process)
# --------------------------------------------------------------------------
SHARD_MAP_SCRIPT = r"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import sys; sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
if len(jax.devices()) < 4:
    print(f"SMAP-SKIP: only {len(jax.devices())} devices"); sys.exit(0)
from repro.core import PageStore, bulk_load, distributed
from repro.core.distributed_jax import (
    ShardedDeviceTable, knn_batch_shard_map, knn_query_batch_sharded,
    window_count_batch_shard_map,
)
from repro.core.queries_jax import DeviceTable, knn_query_batch_jax
try:
    mesh = jax.make_mesh((4,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
except (AttributeError, TypeError):
    mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
pts = rng.random((8192, 2)).astype(np.float32).astype(np.float64)
idx = bulk_load(pts, 250, PageStore(250))
sdev = ShardedDeviceTable.from_index(idx, 4)
assert sdev.m == 4
st = sdev.stacked()
qs = rng.random((8, 2)).astype(np.float32)
# collective two-round k-NN == single-table engine ids
d2, ids = knn_batch_shard_map(st, qs, 8, mesh)
want = knn_query_batch_jax(DeviceTable.from_index(idx), qs, 8)
for i in range(8):
    assert np.array_equal(ids[i], want[i]), (i, ids[i], want[i])
# collective window counts == oracle
los, his = qs - 0.07, qs + 0.07
cnt = window_count_batch_shard_map(st, los, his, mesh)
lo64 = los.astype(np.float64); hi64 = his.astype(np.float64)
oracle = np.array([np.sum(np.all((pts >= l) & (pts <= h), 1))
                   for l, h in zip(lo64, hi64)])
np.testing.assert_array_equal(cnt, oracle)
# shard_build carries global row ids and lands on the NodeTable path
pts32 = pts.astype(np.float32)
out = distributed.shard_build(jnp.asarray(pts32), mesh, levels_local=4)
ri = np.asarray(out[1]).ravel()
valid = ri[ri >= 0]
assert len(np.unique(valid)) == len(valid), "duplicate row ids"
assert valid.min() >= 0 and valid.max() < len(pts)
tables = distributed.shard_build_tables(out, 4)
live = 0
for t in tables:
    t.check_invariants()
    live += int(t.leaf_count[t.leaf_rows()].sum())
assert live == int(np.asarray(out[6]).sum())
sdev2 = ShardedDeviceTable.from_tables(tables, pts)
got = knn_query_batch_sharded(sdev2, qs, 8)
kept = np.isin(np.arange(len(pts)), valid)
for i, q in enumerate(qs):
    d2o = np.sum((pts[kept] - q.astype(np.float64)) ** 2, 1)
    want_d = np.sort(d2o)[:8]
    got_d = np.sort(np.sum((pts[got[i]] - q.astype(np.float64)) ** 2, 1))
    np.testing.assert_allclose(got_d, want_d, rtol=1e-6)
print("SMAP-OK")
"""


def test_shard_map_collective_rounds_4dev():
    res = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SCRIPT], capture_output=True,
        text=True,
        cwd=str(__import__("pathlib").Path(__file__).resolve().parents[1]),
        timeout=600,
    )
    if "SMAP-SKIP" in res.stdout:
        pytest.skip("could not provision 4 virtual devices: "
                    + res.stdout.strip())
    assert "SMAP-OK" in res.stdout, res.stdout + res.stderr
