"""Open-loop serving benchmark: the async frontend under offered load.

Drives :class:`repro.serve.frontend.Frontend` (real clock, dispatcher
thread) with a fixed-rate open-loop generator — arrivals at ``i / rate``
regardless of completions, the honest way to measure a bounded queue:
a closed-loop client self-throttles and can never expose shedding.

Three workload mixes (75% window / 25% k-NN):

  * ``hotspot`` — queries concentrated in an 8% hot cube (the adaptive
    engine's favorite case, and the batch former's: one lane fills fast),
  * ``uniform`` — uniform small windows across the space,
  * ``adversarial`` — fat windows (large result sets), degenerate
    point-thin windows, and far-corner k-NN in one stream, defeating
    both the router's pruning and any single pow2 padding bucket.

Each mix runs at sub- (0.5x), at- (1.0x), and over- (2x) the measured
capacity (a warm ``batch_max`` dispatch timed directly), recording p50 /
p99 latency, achieved throughput, shed + rejection rate, and peak queue
depth into ``BENCH_SERVE.json``.  A separate full-throttle **burst** run
guarantees saturation regardless of machine speed and asserts the
robustness contract: queue depth never exceeds the bound, excess load is
rejected/shed *with certificates* rather than queued without bound, and
every admitted answer is id-identical to the same server queried
offline.

  PYTHONPATH=src python -m benchmarks.bench_serving           # full, writes BENCH_SERVE.json
  PYTHONPATH=src python -m benchmarks.bench_serving --smoke   # CI gate, no write

``--smoke`` runs reduced scale and fails (exit 1) when the structural
contract breaks or when a gated latency/throughput key regresses >30%
(plus a noise floor) against the ``smoke_*`` baselines committed in
BENCH_SERVE.json by the last full run.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import zlib

import numpy as np

from repro.core import PageStore, bulk_load
from repro.core.datasets import osm_like
from repro.core.ioutil import atomic_write_json
from repro.serve.engine import DeviceQueryServer
from repro.serve.frontend import Frontend

from .common import buffer_pages

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_SERVE = ROOT / "BENCH_SERVE.json"

SMOKE_N = 60_000
FULL_N = 600_000
K = 8

# latency keys gated against committed smoke baselines: >30% + noise floor
# fails.  Latency floors are generous — these runs share a CI box with the
# kernel jobs, and a regression that matters here is 2x, not 30ms.
SMOKE_GATED_LATENCY = {
    # floors sized from observed run-to-run spread (queueing delay near
    # capacity swings 25-55% between runs of the same build): the gate
    # catches a serialized dispatcher or lock-contention collapse (p50 in
    # seconds), not scheduler weather
    "hotspot_sub_p50_ms": 150.0,
    "hotspot_sub_p99_ms": 400.0,
    "uniform_sub_p50_ms": 150.0,
    "adversarial_sub_p50_ms": 300.0,
}
# throughput keys: regression = *lower* than baseline by >40%
SMOKE_GATED_THROUGHPUT = {
    "hotspot_at_throughput_qps",
    "uniform_at_throughput_qps",
}
SMOKE_REGRESSION_FRAC = 0.30
SMOKE_THROUGHPUT_FRAC = 0.40
# static ceilings when no baseline is committed (first run, --n override)
SMOKE_CEILING_P50_MS = 500.0
SMOKE_CEILING_P99_MS = 2500.0


def _build_server(n: int, seed: int = 0):
    pts = osm_like(n, seed=seed)
    idx = bulk_load(pts, buffer_pages(pts), PageStore(buffer_pages(pts)))
    srv = DeviceQueryServer.from_index(idx, microbatch=64)
    return pts, srv


def _mix_stream(mix: str, d: int, n: int, seed: int):
    """Deterministic request stream: list of ("window", lo, hi) and
    ("knn", q, k) tuples, 75/25, per-mix geometry."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        kind = "knn" if i % 4 == 3 else "window"
        if mix == "hotspot":
            c = rng.random(d) * 0.08 + 0.45
            half = 0.02
        elif mix == "uniform":
            c = rng.random(d) * 0.9
            half = 0.02
        else:  # adversarial: fat / degenerate / far-corner rotation
            j = i % 3
            c = rng.random(d) * 0.9
            half = (0.2, 0.0, 0.02)[j]
            if j == 2 and kind == "knn":
                c = np.full(d, 0.999)  # far corner: router prunes nothing near
        if kind == "window":
            out.append(("window", np.clip(c - half, 0, 1),
                        np.clip(c + half, 0, 1)))
        else:
            out.append(("knn", np.clip(c, 0, 1), K))
    return out


def warm_server(srv, d: int, batch_max: int = 64) -> None:
    """Compile every pow2 batch bucket for both query kinds up front —
    otherwise the first undersized microbatch of each shape stalls the
    dispatcher on a jit compile and poisons the latency percentiles."""
    rng = np.random.default_rng(3)
    b = 1
    while b <= batch_max:
        c = rng.random((b, d)) * 0.9
        srv.window(np.clip(c - 0.02, 0, 1), np.clip(c + 0.02, 0, 1))
        srv.knn(rng.random((b, d)), K)
        b *= 2


def measure_capacity(srv, d: int, *, n_requests: int = 192,
                     batch_max: int = 64) -> float:
    """End-to-end queries/second *through the frontend* (dispatcher
    thread, batching, locking, per-request bookkeeping included) — the
    raw engine number overstates what an open-loop client can actually
    push, so rates scaled from it would mislabel saturation as "sub"."""
    stream = _mix_stream("uniform", d, n_requests, seed=3)
    fe = Frontend(srv, queue_bound=n_requests + 1,
                  batch_max=batch_max, batch_window_s=0.001).start()
    t0 = time.monotonic()
    for item in stream:
        if item[0] == "window":
            fe.submit_window(item[1], item[2])
        else:
            fe.submit_knn(item[1], item[2])
    fe.stop()  # drains everything through dispatch
    elapsed = time.monotonic() - t0
    return fe.stats.completed / max(elapsed, 1e-9)


def run_open_loop(srv, stream, rate_qps: float, *,
                  queue_bound: int = 256, batch_max: int = 64,
                  batch_window_s: float = 0.002,
                  deadline_s: float | None = None,
                  brownout_high: int | None = None) -> dict:
    """Fixed-rate arrivals: request ``i`` is submitted at ``t0 + i/rate``
    whether or not earlier ones completed (open loop)."""
    fe = Frontend(
        srv, queue_bound=queue_bound, batch_max=batch_max,
        batch_window_s=batch_window_s, default_deadline_s=deadline_s,
        brownout_high=brownout_high,
        brownout_low=None if brownout_high is None else brownout_high // 4,
        brownout_knn_rounds=1,
    ).start()
    reqs = []
    t0 = time.monotonic()
    for i, item in enumerate(stream):
        target = t0 + i / rate_qps
        now = time.monotonic()
        if target > now:
            time.sleep(target - now)
        if item[0] == "window":
            reqs.append(fe.submit_window(item[1], item[2]))
        else:
            reqs.append(fe.submit_knn(item[1], item[2]))
    t_submit_end = time.monotonic()
    fe.stop()  # drains the queue through dispatch
    t_end = time.monotonic()

    lat = np.array([r.latency for r in reqs if r.status == "ok"])
    n = len(reqs)
    st = fe.stats
    out = {
        "offered_qps": round(n / max(t_submit_end - t0, 1e-9), 1),
        "throughput_qps": round(st.completed / max(t_end - t0, 1e-9), 1),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3)
        if lat.size else -1.0,
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3)
        if lat.size else -1.0,
        "shed_rate": round(st.dropped / max(n, 1), 4),
        "rejected": st.rejected,
        "timed_out": st.timed_out,
        "shed": st.shed,
        "depth_peak": st.depth_peak,
        "brownout_batches": st.brownout_batches,
        "batches": st.batches,
    }
    return out, reqs, fe


def saturation_burst(srv, pts, *, queue_bound: int = 64,
                     n_requests: int = 256, seed: int = 9) -> dict:
    """Full-throttle burst (no pacing): saturation is guaranteed on any
    machine, so the structural robustness contract is checkable in CI:

      * peak queue depth never exceeds the bound,
      * the excess is rejected/shed — nonzero, and every dropped request
        carries a completeness certificate,
      * every admitted answer is id-identical to the offline engine.
    """
    d = pts.shape[1]
    stream = _mix_stream("uniform", d, n_requests, seed)
    fe = Frontend(srv, queue_bound=queue_bound, batch_max=32,
                  batch_window_s=0.001).start()
    reqs = []
    for item in stream:
        if item[0] == "window":
            reqs.append(fe.submit_window(item[1], item[2]))
        else:
            reqs.append(fe.submit_knn(item[1], item[2]))
    fe.stop()

    errors = []
    if fe.stats.depth_peak > queue_bound:
        errors.append(
            f"queue depth {fe.stats.depth_peak} exceeded bound {queue_bound}"
        )
    dropped = [r for r in reqs if r.status != "ok"]
    if fe.stats.rejected == 0:
        errors.append("full-throttle burst produced zero rejections — "
                      "admission control never engaged")
    for r in dropped:
        if r.cert is None or r.cert.complete:
            errors.append(f"dropped request {r.seq} ({r.status}) lacks a "
                          "degraded certificate")
            break
    # admitted answers must match the same server queried offline
    served = [(r, it) for r, it in zip(reqs, stream) if r.status == "ok"]
    w = [(r, it) for r, it in served if it[0] == "window"][:32]
    if w:
        los = np.stack([it[1] for _, it in w])
        his = np.stack([it[2] for _, it in w])
        for (r, _), ref in zip(w, srv.window(los, his)):
            if not np.array_equal(np.sort(r.ids), np.sort(ref)):
                errors.append(f"window request {r.seq}: frontend ids "
                              "diverge from offline engine")
                break
    kq = [(r, it) for r, it in served if it[0] == "knn"][:32]
    if kq:
        qs = np.stack([it[1] for _, it in kq])
        for (r, _), ref in zip(kq, srv.knn(qs, K)):
            if not np.array_equal(r.ids, ref):
                errors.append(f"knn request {r.seq}: frontend ids diverge "
                              "from offline engine")
                break
    return {
        "burst_submitted": len(reqs),
        "burst_completed": fe.stats.completed,
        "burst_rejected": fe.stats.rejected,
        "burst_depth_peak": fe.stats.depth_peak,
        "burst_errors": errors,
    }


def run(n: int, *, duration_s: float, seed: int = 0) -> dict:
    pts, srv = _build_server(n, seed=seed)
    d = pts.shape[1]
    res: dict = {"n_points": n, "k": K}

    warm_server(srv, d)
    cap = measure_capacity(srv, d)
    res["capacity_qps"] = round(cap, 1)
    # a Python submit loop tops out well below true device capacity on
    # fast machines; cap the offered rate and record that we did, so the
    # "2x" label stays honest (the burst gate covers true saturation)
    max_offerable = 2000.0
    res["rate_capped"] = bool(2 * cap > max_offerable)

    for mix in ("hotspot", "uniform", "adversarial"):
        for label, mult in (("sub", 0.5), ("at", 1.0), ("2x", 2.0)):
            rate = min(cap * mult, max_offerable * (mult / 2.0))
            n_req = max(int(rate * duration_s), 32)
            stream = _mix_stream(
                mix, d, n_req,
                seed=zlib.crc32(f"{mix}/{label}".encode()) & 0xFFFF,
            )
            # over-capacity runs get a deadline + brownout so the queue
            # turns over instead of serializing the whole backlog at stop
            over = mult > 1.0
            stats, _reqs, _fe = run_open_loop(
                srv, stream, rate,
                queue_bound=256,
                # close batches once a full one could have arrived: a
                # window much shorter than the inter-arrival gap closes
                # 1-2 element batches that pad to pow2 and cost nearly a
                # full dispatch, collapsing effective capacity
                batch_window_s=min(64.0 / rate, 0.25),
                deadline_s=2.0 if over else None,
                brownout_high=192 if over else None,
            )
            for k, v in stats.items():
                res[f"{mix}_{label}_{k}"] = v
    burst = saturation_burst(srv, pts)
    res.update({k: v for k, v in burst.items() if k != "burst_errors"})
    res["burst_ok"] = not burst["burst_errors"]
    if burst["burst_errors"]:
        res["burst_error_detail"] = "; ".join(burst["burst_errors"])
    return res


def smoke_gate(res: dict, use_baselines: bool = True) -> list[str]:
    baselines = {}
    if use_baselines and BENCH_SERVE.exists():
        baselines = json.loads(BENCH_SERVE.read_text())
    failures = []
    if not res.get("burst_ok"):
        failures.append("saturation burst contract: "
                        + res.get("burst_error_detail", "?"))
    for key, floor_ms in SMOKE_GATED_LATENCY.items():
        got = res.get(key, -1.0)
        if got < 0:
            failures.append(f"{key}: missing/errored")
            continue
        base = baselines.get(f"smoke_{key}", -1.0)
        if base > 0:
            limit = max(base * (1 + SMOKE_REGRESSION_FRAC), base + floor_ms)
            if got > limit:
                failures.append(
                    f"{key}: {got:.1f}ms > {limit:.1f}ms "
                    f"(committed smoke baseline {base:.1f}ms +30%)"
                )
        else:
            ceiling = (SMOKE_CEILING_P99_MS if "p99" in key
                       else SMOKE_CEILING_P50_MS)
            if got > ceiling:
                failures.append(f"{key}: {got:.1f}ms > static ceiling "
                                f"{ceiling:.1f}ms (no committed baseline)")
    for key in SMOKE_GATED_THROUGHPUT:
        got = res.get(key, -1.0)
        base = baselines.get(f"smoke_{key}", -1.0)
        if base > 0 and got >= 0 and got < base * (1 - SMOKE_THROUGHPUT_FRAC):
            failures.append(
                f"{key}: {got:.1f} qps < {base * (1 - SMOKE_THROUGHPUT_FRAC):.1f} "
                f"(committed smoke baseline {base:.1f} qps -40%)"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scale, gate against baselines, no write")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--duration", type=float, default=None,
                    help="seconds per (mix, rate) run")
    args = ap.parse_args(argv)

    n = args.n or (SMOKE_N if args.smoke else FULL_N)
    duration = args.duration or (1.5 if args.smoke else 5.0)
    res = run(n, duration_s=duration)
    for k, v in sorted(res.items()):
        print(f"  {k:36s} {v}")

    if args.smoke:
        failures = smoke_gate(res, use_baselines=(n == SMOKE_N))
        if failures:
            print("SMOKE FAIL:\n  " + "\n  ".join(failures))
            return 1
        print("SMOKE OK")
        return 0

    if not res.get("burst_ok"):
        print("BURST GATE FAIL: " + res.get("burst_error_detail", "?"))
        return 1

    # record smoke-scale baselines for the CI gate next to the full numbers
    smoke_res = run(SMOKE_N, duration_s=1.5)
    for key in list(SMOKE_GATED_LATENCY) + sorted(SMOKE_GATED_THROUGHPUT):
        if key in smoke_res:
            res[f"smoke_{key}"] = smoke_res[key]

    atomic_write_json(BENCH_SERVE, res)
    print(f"wrote {BENCH_SERVE}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
