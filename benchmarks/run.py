"""Benchmark orchestrator: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced sizes
  PYTHONPATH=src python -m benchmarks.run --only fig8
"""
from __future__ import annotations

import argparse
import time

from . import (
    bench_adaptive,
    bench_construction,
    bench_dims,
    bench_leafstats,
    bench_parallel,
    bench_queries,
)

SUITES = {
    "table1": lambda q: bench_leafstats.run(n=120_000 if q else 2_000_000),
    "fig7_build": lambda q: bench_construction.run(n=120_000 if q else 2_000_000),
    "fig7_query": lambda q: bench_queries.run(n=120_000 if q else 1_000_000),
    "fig8": lambda q: bench_adaptive.run(n=100_000 if q else 600_000),
    "fig9": lambda q: bench_dims.run(n=60_000 if q else 400_000),
    "fig11": lambda q: bench_parallel.run(n=60_000 if q else 400_000),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", choices=sorted(SUITES))
    args = ap.parse_args(argv)
    todo = {args.only: SUITES[args.only]} if args.only else SUITES
    t0 = time.time()
    for name, fn in todo.items():
        t1 = time.time()
        print(f"\n######## {name} ########")
        fn(args.quick)
        print(f"[{name}: {time.time()-t1:.1f}s]")
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s; "
          f"tables under experiments/")


if __name__ == "__main__":
    main()
