"""Shared benchmark infrastructure: datasets, runners, result tables."""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import ALL_LOADERS, PageStore
from repro.core.datasets import GENERATORS, nycyt_like, osm_like

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "experiments"

# scaled-down evaluation sizes (paper: OSM 1e9 / NYCYT 1e8; the page-I/O
# cost model is scale-faithful, wall-clock is not the metric)
N_OSM = 600_000
N_NYC = 200_000
BUFFER_FRACTION = 0.05  # of dataset pages (paper: 1%..10%)


def dataset(name: str, n: int, d: int = 2, seed: int = 0) -> np.ndarray:
    if name == "osm":
        return osm_like(n, seed)
    if name == "nycyt":
        return nycyt_like(n, d, seed)
    return GENERATORS[name](n, d=d, seed=seed)


def buffer_pages(points: np.ndarray, fraction: float = BUFFER_FRACTION) -> int:
    from repro.core.pagestore import branch_capacity, leaf_capacity

    n, d = points.shape
    p = -(-n // leaf_capacity(d))
    return max(int(p * fraction), branch_capacity(d) + 1)


def build_all(points: np.ndarray, M: int, loaders=None) -> dict:
    out = {}
    for name, loader in (loaders or ALL_LOADERS).items():
        store = PageStore(M)
        t0 = time.time()
        idx = loader(points, M, store)
        out[name] = {
            "index": idx,
            "store": store,
            "build_io": store.stats.total,
            "build_reads": store.stats.reads,
            "build_writes": store.stats.writes,
            "wall_s": round(time.time() - t0, 3),
        }
    return out


def save_table(name: str, rows) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.json"
    path.write_text(json.dumps(rows, indent=2, default=str))
    return path


def print_table(title: str, rows: list[dict], cols: list[str]) -> None:
    print(f"\n== {title} ==")
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
