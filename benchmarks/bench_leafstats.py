"""Paper Table 1 + Figure 4: leaf-node count / perimeter / area + balance."""
from __future__ import annotations

from repro.core import leaf_stats
from repro.core.metrics import overlap_area_2d

from .common import (
    N_OSM,
    build_all,
    buffer_pages,
    dataset,
    print_table,
    save_table,
)


def run(n: int = N_OSM, seed: int = 0) -> list[dict]:
    pts = dataset("osm", n, seed=seed)
    M = buffer_pages(pts)
    built = build_all(pts, M)
    rows = []
    for name, b in sorted(built.items()):
        ls = leaf_stats(b["index"])
        rows.append({
            "index": name,
            "count": ls.count,
            "perimeter": round(ls.total_perimeter, 2),
            "area": round(ls.total_area, 4),
            "avg_fill": round(ls.avg_fill, 3),
            "balance_max_over_mean": round(ls.max_over_mean, 3),
            "overlap_area": round(overlap_area_2d(b["index"]), 5)
            if ls.count < 3000 else "-",
        })
    print_table("Table 1: leaf statistics (OSM-like)", rows,
                ["index", "count", "perimeter", "area", "avg_fill",
                 "balance_max_over_mean", "overlap_area"])
    save_table("table1_leafstats", rows)
    return rows


if __name__ == "__main__":
    run()
