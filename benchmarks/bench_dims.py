"""Paper Figure 9: dimensionality scaling (NYCYT-like, d = 2..5)."""
from __future__ import annotations

import numpy as np

from repro.core import knn_query, window_query

from .common import (
    N_NYC,
    build_all,
    buffer_pages,
    dataset,
    print_table,
    save_table,
)

N_QUERIES = 100


def run(n: int = N_NYC, seed: int = 0) -> list[dict]:
    rows = []
    for d in (2, 3, 4, 5):
        pts = dataset("nycyt", n, d=d, seed=seed)
        M = buffer_pages(pts)
        built = build_all(pts, M)
        rng = np.random.default_rng(seed + d)
        qpts = rng.random((N_QUERIES, d))
        for name, b in sorted(built.items()):
            idx = b["index"]
            idx.store.buffer.clear()
            knn_io = 0
            for q in qpts:
                _, io = knn_query(idx, q, 64)
                knn_io += io.total
            idx.store.buffer.clear()
            win_io = 0
            w = 0.5 * (256 / n) ** (1.0 / d)
            for q in qpts:
                _, io = window_query(idx, q - w, q + w)
                win_io += io.total
            rows.append({
                "d": d,
                "index": name,
                "build_io": b["build_io"],
                "knn64_io": round(knn_io / N_QUERIES, 2),
                "win_io": round(win_io / N_QUERIES, 2),
            })
    print_table("Fig 9: dimensionality scaling (NYCYT-like)", rows,
                ["d", "index", "build_io", "knn64_io", "win_io"])
    save_table("fig9_dims", rows)
    return rows


if __name__ == "__main__":
    run()
