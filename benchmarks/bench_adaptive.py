"""Paper Figure 8: adaptive AMBI vs non-adaptive — cumulative build+query
cost as a function of the number of queries, uniform and focused."""
from __future__ import annotations

import numpy as np

from repro.core import ALL_LOADERS, AMBI, PageStore, knn_query, window_query

from .common import buffer_pages, dataset, print_table, save_table

N = 300_000
CHECKPOINTS = (1, 10, 100, 500)


def _workload(rng, kind: str, focused: bool):
    if focused:
        c = rng.random(2) * 0.06 + np.array([0.58, 0.58])  # dense region
    else:
        c = rng.random(2)
    if kind == "knn":
        return c
    w = 0.015
    return (c - w, c + w)


def _run_workload(kind: str, focused: bool, pts, M) -> list[dict]:
    # non-adaptive: full build first, then queries
    curves: dict[str, list] = {}
    for name, loader in ALL_LOADERS.items():
        store = PageStore(M)
        idx = loader(pts, M, store)
        cum = store.stats.total
        rng = np.random.default_rng(7)
        curve = []
        done = 0
        for cp in CHECKPOINTS:
            while done < cp:
                q = _workload(rng, kind, focused)
                if kind == "knn":
                    _, io = knn_query(idx, q, 64)
                else:
                    _, io = window_query(idx, q[0], q[1])
                cum += io.total
                done += 1
            curve.append(cum)
        curves[name] = curve

    ambi = AMBI(pts, M)
    rng = np.random.default_rng(7)
    cum, done, curve = 0, 0, []
    for cp in CHECKPOINTS:
        while done < cp:
            q = _workload(rng, kind, focused)
            if kind == "knn":
                _, io = ambi.knn(q, 64)
            else:
                _, io = ambi.window(q[0], q[1])
            cum += io.total
            done += 1
        curve.append(cum)
    curves["ambi"] = curve

    rows = []
    for name, curve in sorted(curves.items()):
        row = {"index": name}
        for cp, c in zip(CHECKPOINTS, curve):
            row[f"q{cp}"] = c
        rows.append(row)
    return rows


def run(n: int = N, seed: int = 0) -> dict:
    pts = dataset("osm", n, seed=seed)
    M = buffer_pages(pts)
    out = {}
    for kind in ("knn", "window"):
        for focused in (False, True):
            tag = f"{kind}_{'focused' if focused else 'uniform'}"
            rows = _run_workload(kind, focused, pts, M)
            cols = ["index"] + [f"q{c}" for c in CHECKPOINTS]
            print_table(f"Fig 8 ({tag}): cumulative build+query I/O", rows,
                        cols)
            save_table(f"fig8_{tag}", rows)
            out[tag] = rows
    return out


if __name__ == "__main__":
    run()
