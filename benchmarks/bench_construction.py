"""Paper Figure 7 (left column): construction cost + index size."""
from __future__ import annotations

from .common import (
    N_OSM,
    build_all,
    buffer_pages,
    dataset,
    print_table,
    save_table,
)


def run(n: int = N_OSM, seed: int = 0) -> list[dict]:
    pts = dataset("osm", n, seed=seed)
    M = buffer_pages(pts)
    built = build_all(pts, M)
    fmbi_io = built["fmbi"]["build_io"]
    rows = []
    for name, b in sorted(built.items()):
        idx = b["index"]
        rows.append({
            "index": name,
            "build_io": b["build_io"],
            "reads": b["build_reads"],
            "writes": b["build_writes"],
            "vs_fmbi": round(b["build_io"] / fmbi_io, 2),
            "size_pages": idx.distinct_pages(),
            "wall_s": b["wall_s"],
        })
    print_table(
        f"Fig 7 left: construction (OSM-like n={n}, M={M} pages)",
        rows,
        ["index", "build_io", "reads", "writes", "vs_fmbi", "size_pages",
         "wall_s"],
    )
    save_table("fig7_construction", rows)
    return rows


if __name__ == "__main__":
    run()
