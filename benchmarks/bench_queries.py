"""Paper Figure 7 (columns 2-3): k-NN and window query cost vs k / area."""
from __future__ import annotations

import numpy as np

from repro.core import knn_query, window_query

from .common import (
    N_OSM,
    build_all,
    buffer_pages,
    dataset,
    print_table,
    save_table,
)

N_QUERIES = 200


def run(n: int = N_OSM, seed: int = 0) -> dict:
    pts = dataset("osm", n, seed=seed)
    M = buffer_pages(pts)
    built = build_all(pts, M)
    rng = np.random.default_rng(seed + 1)
    qpts = rng.random((N_QUERIES, 2))

    knn_rows, win_rows = [], []
    for name, b in sorted(built.items()):
        idx = b["index"]
        row = {"index": name}
        for k in (16, 64, 256):
            idx.store.buffer.clear()
            total = 0
            for q in qpts:
                _, io = knn_query(idx, q, k)
                total += io.total
            row[f"knn_k{k}"] = round(total / N_QUERIES, 2)
        knn_rows.append(row)

        row = {"index": name}
        for area_factor in (64, 256, 1024):
            # window area = factor/N of the data space (paper protocol)
            w = 0.5 * (area_factor / n) ** 0.5
            idx.store.buffer.clear()
            total = 0
            for q in qpts:
                _, io = window_query(idx, q - w, q + w)
                total += io.total
            row[f"win_{area_factor}/N"] = round(total / N_QUERIES, 2)
        win_rows.append(row)

    print_table("Fig 7 mid: k-NN query I/O per query", knn_rows,
                ["index", "knn_k16", "knn_k64", "knn_k256"])
    print_table("Fig 7 right: window query I/O per query", win_rows,
                ["index", "win_64/N", "win_256/N", "win_1024/N"])
    save_table("fig7_knn", knn_rows)
    save_table("fig7_window", win_rows)
    return {"knn": knn_rows, "window": win_rows}


if __name__ == "__main__":
    run()
