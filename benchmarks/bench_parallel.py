"""Paper Figure 11: parallel bulk load + distributed window queries vs m."""
from __future__ import annotations

import numpy as np

from repro.core.distributed import parallel_bulk_load, parallel_window_cost
from repro.core.pagestore import leaf_capacity

from .common import N_NYC, dataset, print_table, save_table

N_QUERIES = 60


def run(n: int = N_NYC, seed: int = 0) -> list[dict]:
    rows = []
    for d in (2, 3, 4, 5):
        pts = dataset("nycyt", n, d=d, seed=seed)
        p_total = -(-n // leaf_capacity(d))
        # paper: every server's buffer = 5%/m of the dataset
        scan_cost = p_total  # red line: central full scan
        base = None
        for m in (1, 2, 4, 8):
            M = max(int(0.05 * p_total), 512)
            build = parallel_bulk_load(pts, m, M,
                                       np.random.default_rng(seed))
            rng = np.random.default_rng(seed + 5)
            qio = 0
            w = 0.5 * (256 / n) ** (1.0 / d)
            for _ in range(N_QUERIES):
                c = rng.random(d)
                _, cost = parallel_window_cost(build, c - w, c + w)
                qio += cost
            if m == 1:
                base = build.makespan_io
            rows.append({
                "d": d,
                "m": m,
                "makespan_build_io": build.makespan_io,
                "speedup_vs_m1": round(base / build.makespan_io, 2),
                "central_scan_io": scan_cost,
                "win_io_makespan": round(qio / N_QUERIES, 2),
            })
    print_table("Fig 11: parallel bulk loading (NYCYT-like)", rows,
                ["d", "m", "makespan_build_io", "speedup_vs_m1",
                 "central_scan_io", "win_io_makespan"])
    save_table("fig11_parallel", rows)
    return rows


if __name__ == "__main__":
    run()
