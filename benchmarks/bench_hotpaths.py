"""Hot-path wall-clock benchmark: the scan engine's regression baseline.

Times the four paths the vectorized scan engine owns —

  * Step-2 routing + distribution (MST route, prefix-sum buffer replay,
    subspace gather),
  * Step-3 refinement (presorted minor-SplitTree recursion),
  * single + batched window queries (flat-table frontier traversal),
  * single + batched k-NN queries (vectorized leaf-table pruning),

plus the end-to-end ``bulk_load`` and the JAX candidate-leaf
``window_count``, and writes the numbers to ``BENCH_CORE.json`` at the repo
root.  Future perf PRs diff against that file.

It also times the compiled device query engine (``queries_jax``) on the
same workload, recording ``*_jax_s`` entries next to the CPU-engine
numbers, and the sharded device engine (``distributed_jax``, 4-way
partition behind the subspace-MBB router) as ``*_sharded_*`` entries.
Streaming ingest (PR-9) records sustained insert throughput through the
serving stack (``ingest_sustained_points_per_s`` — a rate, gated from
below) and the 64-window batch latency over the resulting multi-tier
state (``ingest_query_batch_64_s``).

  PYTHONPATH=src python -m benchmarks.bench_hotpaths            # full, writes BENCH_CORE.json
  PYTHONPATH=src python -m benchmarks.bench_hotpaths --smoke    # quick gate, no write

``--smoke`` runs a reduced dataset and fails (exit 1) when a named hot path
(bulk_load, window_batch, knn_batch) regresses more than 30% against the
smoke-scale baselines committed in BENCH_CORE.json (recorded by the full
run under ``smoke_*`` keys), with a small absolute floor so container
timing noise cannot trip the gate on its own.  Paths without a committed
baseline fall back to the static ceilings — a coarse tripwire for
interpreter-loop reintroductions, not a precision benchmark.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import (
    PageStore,
    bulk_load,
    knn_query,
    knn_query_batch,
    window_query,
    window_query_batch,
)
from repro.core.datasets import osm_like
from repro.core.ioutil import atomic_write_json
from repro.core.fmbi import _distribute_vectorized, refine_subspace
from repro.core.pagestore import branch_capacity, leaf_capacity
from repro.core.splittree import build_group_median_tree

from .common import buffer_pages

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_CORE = ROOT / "BENCH_CORE.json"

# seed (pre-vectorization, commit b71a949) wall clock for bulk_load on the
# 600k OSM-like dataset on the reference container — the baseline the
# >= 5x acceptance criterion is measured against
SEED_BULK_LOAD_600K_S = 5.31

# --smoke ceilings (seconds): an order of magnitude above current numbers;
# only a reintroduced interpreter loop should trip these
SMOKE_CEILINGS_S = {
    "step2_route_distribute": 1.0,
    "refine": 1.5,
    "bulk_load": 4.0,
    "window_single": 2.0,
    "window_batch": 1.5,
    "knn_single": 2.0,
    "knn_batch": 1.5,
    "window_batch_fused": 1.5,
    "knn_batch_fused": 1.5,
    "window_batch_sharded": 2.0,
    "knn_batch_sharded": 2.0,
    "adaptive_serve_first": 8.0,
    "adaptive_serve_steady": 1.5,
    "adaptive_recovery": 8.0,
    "ingest_query": 2.0,
}

# hot paths gated against the committed smoke-scale baselines: >30%
# regression (plus an absolute noise floor) fails CI
SMOKE_GATED = {
    "bulk_load": "bulk_load_s",
    "window_batch": "window_batch_64_s",
    "knn_batch": "knn_batch_64_k16_s",
    "window_batch_fused": "window_batch_fused_64_s",
    "knn_batch_fused": "knn_batch_fused_64_k16_s",
    "window_batch_sharded": "window_batch_sharded_64_s",
    "knn_batch_sharded": "knn_batch_sharded_64_k16_s",
    "adaptive_serve_first": "adaptive_serve_first_result_s",
    "adaptive_serve_steady": "adaptive_serve_steady_batch_64_s",
    "adaptive_recovery": "adaptive_recovery_s",
    "ingest_sustained": "ingest_sustained_points_per_s",
    "ingest_query": "ingest_query_batch_64_s",
}
# gated entries that are rates (higher is better): the gate inverts — a
# fresh run fails when it lands >30% BELOW the committed baseline
SMOKE_RATE_GATED = {"ingest_sustained"}
# static floors for rate paths with no committed baseline (points/s)
SMOKE_RATE_FLOORS = {"ingest_sustained": 2_000.0}
SMOKE_REGRESSION_FRAC = 0.30
SMOKE_NOISE_FLOOR_S = 0.05
# one-shot cold-start paths carry jit-compile variance well above the
# default floor; a regression that matters there costs seconds, not 100ms
SMOKE_NOISE_FLOOR_OVERRIDES_S = {
    "adaptive_serve_first": 0.5,
    "adaptive_recovery": 0.5,
}
SMOKE_N = 120_000


def _timed(fn, repeats: int = 1) -> float:
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n: int = 600_000, seed: int = 0, repeats: int = 3) -> dict:
    pts = osm_like(n, seed=seed)
    d = pts.shape[1]
    c_l, c_b = leaf_capacity(d), branch_capacity(d)
    M = buffer_pages(pts)
    alpha = max(M // c_b, 1)
    if n <= c_b * alpha * c_l:
        raise SystemExit(
            f"n={n} is smaller than one Step-1 sample "
            f"({c_b * alpha * c_l} points); use a larger --n"
        )
    results: dict[str, float] = {}

    # ---- Step-2 routing + distribution (isolated) -----------------------
    sample = c_b * alpha * c_l
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(pts))
    samp_idx, rest_idx = np.sort(perm[:sample]), np.sort(perm[sample:])
    mst, _, samp_assign = build_group_median_tree(
        pts[samp_idx], n_groups=c_b, group_pages=alpha, page_points=c_l
    )

    def step2():
        assign = mst.route(pts[rest_idx])
        _distribute_vectorized(
            assign, rest_idx, samp_idx, samp_assign,
            c_b, c_l, M, alpha, PageStore(M),
        )

    results["step2_route_distribute_s"] = _timed(step2, repeats)

    # ---- Step-3 refine (isolated, one buffer-sized subspace per run) ----
    assign = mst.route(pts[rest_idx])
    sub_idx, *_ = _distribute_vectorized(
        assign, rest_idx, samp_idx, samp_assign,
        c_b, c_l, M, alpha, PageStore(M),
    )

    def refine():
        store = PageStore(M)
        for s in range(c_b):
            if len(sub_idx[s]):
                refine_subspace(pts, sub_idx[s], c_l, c_b, store)

    results["refine_s"] = _timed(refine, repeats)

    # ---- end-to-end bulk load -------------------------------------------
    results["bulk_load_s"] = _timed(lambda: bulk_load(pts, M, PageStore(M)),
                                    repeats)
    results["seed_bulk_load_600k_s"] = SEED_BULK_LOAD_600K_S
    if n == 600_000:
        results["bulk_load_speedup_vs_seed"] = round(
            SEED_BULK_LOAD_600K_S / results["bulk_load_s"], 2
        )

    # ---- query paths (single + batched) ---------------------------------
    idx = bulk_load(pts, M, PageStore(M))
    qrng = np.random.default_rng(1)
    centers = qrng.random((64, d)) * 0.9
    los, his = centers - 0.02, centers + 0.02
    results["window_single_64_s"] = _timed(
        lambda: [window_query(idx, los[i], his[i]) for i in range(64)],
        repeats,
    )
    results["window_batch_64_s"] = _timed(
        lambda: window_query_batch(idx, los, his), repeats
    )
    qs = qrng.random((64, d))
    results["knn_single_64_k16_s"] = _timed(
        lambda: [knn_query(idx, qs[i], 16) for i in range(64)], repeats
    )
    results["knn_batch_64_k16_s"] = _timed(
        lambda: knn_query_batch(idx, qs, 16), repeats
    )

    # ---- compiled device query engine (NodeTable -> DeviceTable) --------
    try:
        from repro.core.queries_jax import (
            DeviceTable,
            knn_query_batch_jax,
            window_query_batch_jax,
        )

        dev = DeviceTable.from_index(idx)
        window_query_batch_jax(dev, los, his)  # compile
        results["window_batch_64_jax_s"] = _timed(
            lambda: window_query_batch_jax(dev, los, his), repeats
        )
        knn_query_batch_jax(dev, qs, 16)  # compile
        results["knn_batch_64_k16_jax_s"] = _timed(
            lambda: knn_query_batch_jax(dev, qs, 16), repeats
        )

        # fused traversal+scan (PR-7 second-gen path) — explicit pin so the
        # gate survives a REPRO_FUSED default flip, plus the first-gen
        # baseline for the before/after diff
        results["window_batch_fused_64_s"] = _timed(
            lambda: window_query_batch_jax(dev, los, his, fused=True),
            repeats,
        )
        results["knn_batch_fused_64_k16_s"] = _timed(
            lambda: knn_query_batch_jax(dev, qs, 16, fused=True), repeats
        )
        window_query_batch_jax(dev, los, his, fused=False)  # compile
        results["window_batch_unfused_64_s"] = _timed(
            lambda: window_query_batch_jax(dev, los, his, fused=False),
            repeats,
        )
        knn_query_batch_jax(dev, qs, 16, fused=False)  # compile
        results["knn_batch_unfused_64_k16_s"] = _timed(
            lambda: knn_query_batch_jax(dev, qs, 16, fused=False), repeats
        )

        # bf16 compressed-MBB layout (half-width traversal bounds,
        # certified f32 re-check)
        dev_c = DeviceTable.from_index(idx, compressed=True)
        window_query_batch_jax(dev_c, los, his, fused=True)  # compile
        results["window_batch_fused_bf16_64_s"] = _timed(
            lambda: window_query_batch_jax(dev_c, los, his, fused=True),
            repeats,
        )
        knn_query_batch_jax(dev_c, qs, 16, fused=True)  # compile
        results["knn_batch_fused_bf16_64_k16_s"] = _timed(
            lambda: knn_query_batch_jax(dev_c, qs, 16, fused=True), repeats
        )

        # roofline estimate: bytes the fused kernels move on this workload
        # vs the measured wall clock (CPU numbers are a sanity floor; the
        # TPU projection in DESIGN_PERF.md uses the same byte counts)
        try:
            from repro import roofline as rf

            lo_np = np.asarray(dev.leaf_lo)
            hi_np = np.asarray(dev.leaf_hi)
            lf = los.astype(np.float32)
            hf = his.astype(np.float32)
            hit = np.all(
                (lo_np[None] <= hf[:, None]) & (hi_np[None] >= lf[:, None]),
                axis=2,
            )
            p0 = int(hit.sum())
            n_boxes = dev.n_leaves + sum(
                lv[0].shape[0] for lv in dev.levels
            )
            s = dev.leaf_pts.shape[1]
            w_bytes = rf.bytes_box_hits_tiled(
                n_boxes, 64, d
            ) + rf.bytes_pair_window_ids(p0, s, d)
            results["window_fused_pairs"] = p0
            results["window_fused_bytes_moved"] = w_bytes
            results["window_fused_cpu_gbps"] = round(
                rf.kernel_roofline(
                    w_bytes, results["window_batch_fused_64_s"]
                )["achieved_gbps"], 3,
            )
            c0 = 8  # first-round candidate leaves per query (k=16, s>=32)
            k_bytes = rf.bytes_leaf_mindist_tiled(
                64, dev.n_leaves, d
            ) + rf.bytes_pair_dist2(64 * c0, s, d)
            results["knn_fused_bytes_moved"] = k_bytes
            results["knn_fused_cpu_gbps"] = round(
                rf.kernel_roofline(
                    k_bytes, results["knn_batch_fused_64_k16_s"]
                )["achieved_gbps"], 3,
            )
        except Exception as e:  # pragma: no cover - estimate only
            results["roofline_error"] = str(e)
    except Exception as e:  # pragma: no cover - accelerator-env dependent
        results["window_batch_64_jax_s"] = -1.0
        results["knn_batch_64_k16_jax_s"] = -1.0
        results["window_batch_fused_64_s"] = -1.0
        results["knn_batch_fused_64_k16_s"] = -1.0
        results["device_engine_error"] = str(e)

    # ---- sharded device engine (4-way partition + MBB router) ------------
    try:
        from repro.core.distributed_jax import (
            ShardedDeviceTable,
            knn_query_batch_sharded,
            window_query_batch_sharded,
        )

        sdev = ShardedDeviceTable.from_index(idx, 4)
        window_query_batch_sharded(sdev, los, his)  # compile
        results["window_batch_sharded_64_s"] = _timed(
            lambda: window_query_batch_sharded(sdev, los, his), repeats
        )
        knn_query_batch_sharded(sdev, qs, 16)  # compile
        results["knn_batch_sharded_64_k16_s"] = _timed(
            lambda: knn_query_batch_sharded(sdev, qs, 16), repeats
        )
    except Exception as e:  # pragma: no cover - accelerator-env dependent
        results["window_batch_sharded_64_s"] = -1.0
        results["knn_batch_sharded_64_k16_s"] = -1.0
        results["sharded_engine_error"] = str(e)

    # ---- adaptive device serving (hotspot workload) ----------------------
    # time-to-first-result: boot DeviceQueryServer from the
    # single-unrefined-root AMBI state and answer the first hotspot batch
    # (host refinement + delta upload included); steady state: the same
    # hotspot batch once the hot set is resident (pure device dispatch)
    try:
        from repro.core import AMBI
        from repro.serve.engine import DeviceQueryServer

        hot_c = qrng.random((64, d)) * 0.08 + 0.45
        hot_c = hot_c.astype(np.float32).astype(np.float64)
        hot_lo, hot_hi = hot_c - 0.02, hot_c + 0.02

        def first_result():
            ambi = AMBI(pts, M)
            srv = DeviceQueryServer.from_ambi(ambi, microbatch=64)
            srv.window(hot_lo, hot_hi)
            return srv

        t0 = time.perf_counter()
        adaptive_srv = first_result()
        results["adaptive_serve_first_result_s"] = time.perf_counter() - t0
        adaptive_srv.window(hot_lo, hot_hi)  # compile/warm the hot path
        results["adaptive_serve_steady_batch_64_s"] = _timed(
            lambda: adaptive_srv.window(hot_lo, hot_hi), repeats
        )
        results["adaptive_serve_cold_queries"] = (
            adaptive_srv.stats.cold_queries
        )
        results["adaptive_serve_grafts"] = adaptive_srv.stats.grafts
    except Exception as e:  # pragma: no cover - accelerator-env dependent
        results["adaptive_serve_first_result_s"] = -1.0
        results["adaptive_serve_steady_batch_64_s"] = -1.0
        results["adaptive_serve_error"] = str(e)

    # ---- adaptive crash recovery (snapshot + journal replay reboot) ------
    # a durable adaptive server journals the hotspot batch's cold ops;
    # `recover` then reboots it — snapshot load, journal replay against
    # the restored rng/page-store state, and the device re-export — and
    # must land on the bit-identical table (asserted, not just timed)
    try:
        import shutil
        import tempfile

        from repro.core import AMBI
        from repro.serve.engine import DeviceQueryServer

        tmp = pathlib.Path(tempfile.mkdtemp(prefix="bench_recovery_"))
        try:
            srv = DeviceQueryServer.from_ambi(
                AMBI(pts, M), microbatch=64,
                journal_path=tmp / "ops.journal",
                snapshot_path=tmp / "snap.npz",
            )
            srv.window(hot_lo, hot_hi)
            results["adaptive_recovery_journal_records"] = (
                srv.stats.journal_records
            )
            t0 = time.perf_counter()
            recovered = DeviceQueryServer.recover(
                tmp / "snap.npz", tmp / "ops.journal", microbatch=64
            )
            results["adaptive_recovery_s"] = time.perf_counter() - t0
            if not recovered.ambi.table.equals(srv.ambi.table):
                raise RuntimeError(
                    "recovered table diverged from the live server"
                )
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    except Exception as e:  # pragma: no cover - accelerator-env dependent
        results["adaptive_recovery_s"] = -1.0
        results["adaptive_recovery_error"] = str(e)

    # ---- streaming ingest (LSM tiers, delta-only device refresh) ---------
    # sustained throughput: batched inserts through the serving stack —
    # memtable appends, flushes, tier merges AND the incremental device
    # refresh after each mutation; then the 64-window batch latency on the
    # resulting multi-tier state (what a reader pays mid-ingest)
    try:
        from repro.core import StreamingIndex
        from repro.serve.engine import DeviceQueryServer

        stream = StreamingIndex(pts, buffer_pages=M)
        ingest_srv = DeviceQueryServer.from_streaming(stream, microbatch=64)
        ingest_n = min(32_768, max(4_096, n // 16))
        irng = np.random.default_rng(5)
        feed = irng.random((ingest_n, d))
        t0 = time.perf_counter()
        for off in range(0, ingest_n, 1024):
            ingest_srv.insert(feed[off:off + 1024])
        dt = time.perf_counter() - t0
        results["ingest_sustained_points_per_s"] = round(ingest_n / dt, 1)
        results["ingest_flushes"] = stream.flushes
        results["ingest_tier_merges"] = stream.merges + stream.fusions
        ingest_srv.window(los, his)  # compile/warm on the final tier shapes
        results["ingest_query_batch_64_s"] = _timed(
            lambda: ingest_srv.window(los, his), repeats
        )
    except Exception as e:  # pragma: no cover - accelerator-env dependent
        results["ingest_sustained_points_per_s"] = -1.0
        results["ingest_query_batch_64_s"] = -1.0
        results["ingest_error"] = str(e)

    # ---- JAX candidate-leaf window_count --------------------------------
    try:
        import jax.numpy as jnp

        from repro.core import jax_index

        levels = 10
        padded, ids = jax_index.pad_points(pts.astype(np.float32), levels)
        jidx = jax_index.build(jnp.asarray(padded), levels,
                               jnp.asarray(ids, np.int32))
        jl = jnp.asarray(los.astype(np.float32))
        jh = jnp.asarray(his.astype(np.float32))
        jax_index.window_count(jidx, jl, jh)  # compile
        results["jax_window_count_64_s"] = _timed(
            lambda: jax_index.window_count(jidx, jl, jh).block_until_ready(),
            repeats,
        )
    except Exception as e:  # pragma: no cover - accelerator-env dependent
        results["jax_window_count_64_s"] = -1.0
        results["jax_window_count_error"] = str(e)

    return results


def run_scale(n: int = 10_000_000, seed: int = 7) -> dict:
    """10M-point scaling gate: end-to-end bulk load, fused device queries,
    and sampled parity against the NumPy engine.

    Recorded under ``*_10m_s`` keys in BENCH_CORE.json.  Parity is asserted,
    not just timed: a divergence raises and the keys come back as error
    sentinels, which the full run reports.
    """
    results: dict[str, float] = {}
    try:
        pts = osm_like(n, seed=seed)
        d = pts.shape[1]
        M = buffer_pages(pts)
        t0 = time.perf_counter()
        idx = bulk_load(pts, M, PageStore(M))
        results["bulk_load_10m_s"] = time.perf_counter() - t0

        from repro.core.queries_jax import (
            DeviceTable,
            knn_query_batch_jax,
            window_query_batch_jax,
        )

        dev = DeviceTable.from_index(idx, compressed=True)
        qrng = np.random.default_rng(11)
        centers = qrng.random((64, d)) * 0.9
        los, his = centers - 0.01, centers + 0.01
        qs = qrng.random((64, d))
        window_query_batch_jax(dev, los, his, fused=True)  # compile
        results["window_batch_64_jax_10m_s"] = _timed(
            lambda: window_query_batch_jax(dev, los, his, fused=True), 2
        )
        knn_query_batch_jax(dev, qs, 16, fused=True)  # compile
        results["knn_batch_64_k16_jax_10m_s"] = _timed(
            lambda: knn_query_batch_jax(dev, qs, 16, fused=True), 2
        )

        # sampled parity vs the NumPy engine (8 windows + 8 knn queries)
        got_w = window_query_batch_jax(dev, los[:8], his[:8], fused=True)
        ref_w, _ = window_query_batch(idx, los[:8], his[:8])
        for a, b in zip(ref_w, got_w):
            if set(np.asarray(a).tolist()) != set(np.asarray(b).tolist()):
                raise RuntimeError("10M window parity diverged")
        got_k = knn_query_batch_jax(dev, qs[:8], 16, fused=True)
        ref_k, _ = knn_query_batch(idx, qs[:8], 16)
        for a, b in zip(ref_k, got_k):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                raise RuntimeError("10M knn parity diverged")
        results["scale_10m_parity"] = 1.0
        results["scale_10m_n_leaves"] = dev.n_leaves
    except Exception as e:  # pragma: no cover - memory/env dependent
        results.setdefault("bulk_load_10m_s", -1.0)
        results["window_batch_64_jax_10m_s"] = -1.0
        results["knn_batch_64_k16_jax_10m_s"] = -1.0
        results["scale_10m_parity"] = 0.0
        results["scale_10m_error"] = str(e)
    return results


def smoke_gate(res: dict, use_baselines: bool = True) -> list[str]:
    """Diff fresh smoke timings against the committed baselines.

    A named hot path fails when it exceeds the committed ``smoke_<key>``
    value by more than ``SMOKE_REGRESSION_FRAC`` *and* by more than the
    absolute noise floor.  Paths without a committed baseline (older
    BENCH_CORE.json, a missing file, or a ``--n`` override that makes the
    workload incomparable to the SMOKE_N baselines) fall back to the
    static ceilings.
    """
    baselines = {}
    if use_baselines and BENCH_CORE.exists():
        baselines = json.loads(BENCH_CORE.read_text())
    failures = []
    for name, key in SMOKE_GATED.items():
        got = res[key]
        if got < 0:  # error sentinel: the path under gate never executed
            failures.append(f"{name}: errored instead of running "
                            "(see *_error entry in the results)")
            continue
        base = baselines.get(f"smoke_{key}", -1.0)
        if name in SMOKE_RATE_GATED:  # higher is better: gate the floor
            if base > 0:
                limit = base * (1 - SMOKE_REGRESSION_FRAC)
                if got < limit:
                    failures.append(
                        f"{name}: {got:.1f}/s < {limit:.1f}/s "
                        f"(committed smoke baseline {base:.1f}/s -30%)"
                    )
            elif got < SMOKE_RATE_FLOORS[name]:
                failures.append(
                    f"{name}: {got:.1f}/s < static floor "
                    f"{SMOKE_RATE_FLOORS[name]:.1f}/s (no committed baseline)"
                )
            continue
        if base > 0:
            floor = SMOKE_NOISE_FLOOR_OVERRIDES_S.get(
                name, SMOKE_NOISE_FLOOR_S
            )
            limit = max(base * (1 + SMOKE_REGRESSION_FRAC), base + floor)
            if got > limit:
                failures.append(
                    f"{name}: {got:.3f}s > {limit:.3f}s "
                    f"(committed smoke baseline {base:.3f}s +30%)"
                )
        elif got > SMOKE_CEILINGS_S[name]:
            failures.append(
                f"{name}: {got:.3f}s > static ceiling "
                f"{SMOKE_CEILINGS_S[name]:.3f}s (no committed baseline)"
            )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced size, gate against ceilings, no JSON write")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--scale-n", type=int, default=10_000_000,
                    help="10M scaling-gate size for the full run")
    ap.add_argument("--no-scale", action="store_true",
                    help="skip the 10M scaling gate in the full run")
    args = ap.parse_args(argv)

    n = args.n or (SMOKE_N if args.smoke else 600_000)
    # smoke takes best-of-2 so one scheduler hiccup cannot trip the
    # 30%-regression gate against the best-of-3 committed baselines
    res = run(n=n, repeats=2 if args.smoke else 3)
    res["n_points"] = n
    for k, v in sorted(res.items()):
        print(f"  {k:32s} {v}")

    if args.smoke:
        failures = smoke_gate(res, use_baselines=(n == SMOKE_N))
        checks = {
            "step2_route_distribute": res["step2_route_distribute_s"],
            "refine": res["refine_s"],
            "window_single": res["window_single_64_s"],
            "knn_single": res["knn_single_64_k16_s"],
        }
        for name, got in checks.items():
            if got > SMOKE_CEILINGS_S[name]:
                failures.append(
                    f"{name}: {got:.3f}s > ceiling "
                    f"{SMOKE_CEILINGS_S[name]:.3f}s"
                )
        if failures:
            print("SMOKE FAIL:\n  " + "\n  ".join(failures))
            return 1
        print("SMOKE OK")
        return 0

    # 10M scaling gate: bulk load + fused device queries + sampled parity
    if not args.no_scale:
        scale = run_scale(n=args.scale_n)
        res.update(scale)
        for k, v in sorted(scale.items()):
            print(f"  {k:32s} {v}")
        if not scale.get("scale_10m_parity"):
            print("SCALE GATE FAIL: " + scale.get("scale_10m_error", "?"))
            return 1

    # record smoke-scale baselines for the CI regression gate alongside the
    # full-scale numbers (same container, best-of-repeats)
    smoke_res = run(n=SMOKE_N, repeats=3)
    for key in SMOKE_GATED.values():
        res[f"smoke_{key}"] = smoke_res[key]

    # merge over the committed file: keys this run skipped (e.g. the 10M
    # scaling numbers under --no-scale) must survive the rewrite
    out = {}
    if BENCH_CORE.exists():
        out = json.loads(BENCH_CORE.read_text())
    out.update(res)
    atomic_write_json(BENCH_CORE, out)
    print(f"wrote {BENCH_CORE}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
